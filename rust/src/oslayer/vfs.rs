//! The `pread` path: page cache + readahead + SSD, timed.
//!
//! `Vfs::pread` walks the requested range one OS page at a time exactly
//! like `do_generic_file_read`: cache hits copy out; a touched
//! `PG_readahead` marker triggers asynchronous window extension; a miss
//! runs synchronous on-demand readahead and blocks until the page's
//! covering SSD command completes.  The call is computed synchronously
//! against the virtual clock and returns its completion time — the event
//! calendar only sees whole preads, which keeps simulation cost per page
//! at a few nanoseconds.

use super::page_cache::{CachedFile, FileId, PageState, OS_PAGE};
use super::readahead::{absent_span, ondemand_readahead, RaDecision};
use super::storage::IoDone;
use crate::config::{CpuConfig, ReadaheadConfig, SsdConfig};
use crate::device::ssd::Ssd;
use crate::sim::Time;

/// Outcome of one timed pread.
#[derive(Debug, Clone, Copy, Default)]
pub struct PreadStats {
    /// Completion (return-to-caller) time.
    pub done: Time,
    /// Time spent blocked waiting for SSD completions.
    pub blocked_ns: Time,
    /// Pages copied to the caller.
    pub pages: u64,
    /// Pages that were already present (cache hits).
    pub hits: u64,
    /// SSD commands this call submitted (sync + async readahead).
    pub ssd_cmds: u64,
}

#[derive(Debug, Default, Clone)]
pub struct VfsStats {
    pub preads: u64,
    pub bytes: u64,
    pub blocked_ns: Time,
    pub hits: u64,
    pub misses: u64,
    pub ra_windows: u64,
    pub ra_async_windows: u64,
    /// Of `preads`, calls that covered a coalesced multi-request union
    /// ([`Vfs::pread_coalesced`]).
    pub merged_preads: u64,
    /// Requests absorbed into those unions (≥ 2 per merged pread).
    pub merged_parts: u64,
}

impl VfsStats {
    /// Fold another counter set into this one — completion drains merge
    /// reader-pool deltas, end-of-run reports sum per-thread storages.
    pub fn add(&mut self, other: &VfsStats) {
        self.preads += other.preads;
        self.bytes += other.bytes;
        self.blocked_ns += other.blocked_ns;
        self.hits += other.hits;
        self.misses += other.misses;
        self.ra_windows += other.ra_windows;
        self.ra_async_windows += other.ra_async_windows;
        self.merged_preads += other.merged_preads;
        self.merged_parts += other.merged_parts;
    }
}

#[derive(Debug)]
pub struct Vfs {
    files: Vec<CachedFile>,
    pub ssd: Ssd,
    cpu: CpuConfig,
    ra_max_pages: u64,
    ra_enabled: bool,
    /// RAMfs mode: every page is always resident (Fig 7 isolation).
    pub ramfs: bool,
    pub stats: VfsStats,
    /// Fixed per-page cost: find_get_page + bookkeeping (ns).
    page_lookup_ns: Time,
    /// Asynchronous submissions ([`crate::oslayer::Storage::submit`])
    /// whose modeled completion the caller has not drained yet.
    pub(crate) pending: Vec<IoDone>,
    pub(crate) next_ticket: u64,
}

impl Vfs {
    pub fn new(ssd_cfg: &SsdConfig, cpu: &CpuConfig, ra: &ReadaheadConfig, ramfs: bool) -> Self {
        Vfs {
            files: Vec::new(),
            ssd: Ssd::new(ssd_cfg),
            cpu: cpu.clone(),
            ra_max_pages: (ra.max_bytes / OS_PAGE).max(1),
            ra_enabled: ra.enabled,
            ramfs,
            stats: VfsStats::default(),
            page_lookup_ns: 300,
            pending: Vec::new(),
            next_ticket: 0,
        }
    }

    /// Register a file of `size` bytes; returns its id.
    pub fn open(&mut self, size: u64) -> FileId {
        self.files.push(CachedFile::new(size));
        FileId(self.files.len() - 1)
    }

    pub fn file(&self, id: FileId) -> &CachedFile {
        &self.files[id.0]
    }

    /// `echo 3 > /proc/sys/vm/drop_caches` + fresh fd (per-experiment).
    pub fn drop_caches(&mut self) {
        for f in &mut self.files {
            f.drop_caches();
        }
        self.ssd.reset();
        self.stats = VfsStats::default();
        self.pending.clear();
    }

    #[inline]
    fn page_cost(&self) -> Time {
        self.page_lookup_ns + (OS_PAGE as f64 / self.cpu.copy_bw) as Time
    }

    /// Timed pread: returns completion time + accounting.
    pub fn pread(&mut self, now: Time, id: FileId, offset: u64, len: u64) -> PreadStats {
        let mut st = PreadStats::default();
        let mut t = now + self.cpu.syscall_ns;
        let size = self.files[id.0].size;
        assert!(offset < size, "pread past EOF: {offset} >= {size}");
        let len = len.min(size - offset);

        if self.ramfs {
            let pages = len.div_ceil(OS_PAGE);
            t += pages * self.page_cost();
            st.done = t;
            st.pages = pages;
            st.hits = pages;
            self.stats.preads += 1;
            self.stats.bytes += len;
            self.stats.hits += pages;
            return st;
        }

        let first = offset / OS_PAGE;
        let last = (offset + len - 1) / OS_PAGE;
        let mut p = first;
        while p <= last {
            let remaining = last - p + 1;
            match self.files[id.0].slot(p).state() {
                PageState::Present => {
                    st.hits += 1;
                    self.stats.hits += 1;
                    self.maybe_async_trigger(t, id, p, remaining, &mut st, false);
                }
                PageState::InFlight => {
                    let ready = self.files[id.0].slot(p).ready;
                    if ready > t {
                        st.blocked_ns += ready - t;
                        t = ready;
                    }
                    self.files[id.0].mark_present(p);
                    self.maybe_async_trigger(t, id, p, remaining, &mut st, false);
                }
                PageState::Absent => {
                    self.stats.misses += 1;
                    self.sync_fault(t, id, p, remaining, &mut st, false);
                    let ready = self.files[id.0].slot(p).ready;
                    if ready > t {
                        st.blocked_ns += ready - t;
                        t = ready;
                    }
                    self.files[id.0].mark_present(p);
                    // The faulting page may itself carry the marker (fully
                    // async windows put it at the window start); consume it
                    // *without* retriggering — the window was just read.
                    self.files[id.0].set_marker(p, false);
                }
            }
            t += self.page_cost();
            st.pages += 1;
            p += 1;
        }
        self.files[id.0].ra.prev_page = last as i64;
        st.done = t;
        self.stats.preads += 1;
        self.stats.bytes += len;
        self.stats.blocked_ns += st.blocked_ns;
        st
    }

    /// Timed pread over the union of `parts` coalesced requests — the
    /// host engine's `gpufs.host_coalesce = adjacent` entry point.  Costs
    /// exactly one pread of `len` bytes (one syscall, one page walk:
    /// that is the point of merging — like `preadv`, the kernel path is
    /// paid once for the whole union) and additionally counts the merge
    /// in [`VfsStats::merged_preads`] / [`VfsStats::merged_parts`].
    pub fn pread_coalesced(
        &mut self,
        now: Time,
        id: FileId,
        offset: u64,
        len: u64,
        parts: u64,
    ) -> PreadStats {
        debug_assert!(parts >= 2, "coalesced pread needs at least two parts");
        let st = self.pread(now, id, offset, len);
        self.stats.merged_preads += 1;
        self.stats.merged_parts += parts;
        st
    }

    /// The submit half of an asynchronous pread (`host.io_depth > 1`):
    /// the same page walk as [`Vfs::pread`], but the caller pays only
    /// the CPU side (syscall + per-page lookup/copy bookkeeping) and
    /// never blocks.  Faulted windows go to the device through the
    /// queued path ([`Ssd::read_queued`]), so commands from a deep host
    /// window overlap their per-command overhead.  Pages stay
    /// `InFlight` until a later touch finds their command complete —
    /// blocking is replaced by the returned completion time.
    ///
    /// Returns `(stats, io_done)`: `stats.done` is when the *submit
    /// call* returns to the caller (CPU only, `blocked_ns = 0`) and
    /// `io_done >= stats.done` is when the last covering SSD command
    /// has landed — the instant the bytes are stageable.
    pub fn pread_submit(
        &mut self,
        now: Time,
        id: FileId,
        offset: u64,
        len: u64,
    ) -> (PreadStats, Time) {
        let mut st = PreadStats::default();
        let mut t = now + self.cpu.syscall_ns;
        let size = self.files[id.0].size;
        assert!(offset < size, "pread past EOF: {offset} >= {size}");
        let len = len.min(size - offset);

        if self.ramfs {
            let pages = len.div_ceil(OS_PAGE);
            t += pages * self.page_cost();
            st.done = t;
            st.pages = pages;
            st.hits = pages;
            self.stats.preads += 1;
            self.stats.bytes += len;
            self.stats.hits += pages;
            return (st, t);
        }

        let mut io_ready: Time = 0;
        let first = offset / OS_PAGE;
        let last = (offset + len - 1) / OS_PAGE;
        for p in first..=last {
            let remaining = last - p + 1;
            match self.files[id.0].slot(p).state() {
                PageState::Present => {
                    st.hits += 1;
                    self.stats.hits += 1;
                    self.maybe_async_trigger(t, id, p, remaining, &mut st, true);
                }
                PageState::InFlight => {
                    let ready = self.files[id.0].slot(p).ready;
                    io_ready = io_ready.max(ready);
                    if ready <= t {
                        self.files[id.0].mark_present(p);
                    }
                    self.maybe_async_trigger(t, id, p, remaining, &mut st, true);
                }
                PageState::Absent => {
                    self.stats.misses += 1;
                    self.sync_fault(t, id, p, remaining, &mut st, true);
                    io_ready = io_ready.max(self.files[id.0].slot(p).ready);
                    // Same marker rule as the blocking walk: the freshly
                    // faulted page must not retrigger its own window.
                    self.files[id.0].set_marker(p, false);
                }
            }
            t += self.page_cost();
            st.pages += 1;
        }
        self.files[id.0].ra.prev_page = last as i64;
        st.done = t;
        self.stats.preads += 1;
        self.stats.bytes += len;
        (st, t.max(io_ready))
    }

    /// [`Vfs::pread_submit`] over a coalesced union — the async twin of
    /// [`Vfs::pread_coalesced`], with the same merge accounting.
    pub fn pread_coalesced_submit(
        &mut self,
        now: Time,
        id: FileId,
        offset: u64,
        len: u64,
        parts: u64,
    ) -> (PreadStats, Time) {
        debug_assert!(parts >= 2, "coalesced pread needs at least two parts");
        let out = self.pread_submit(now, id, offset, len);
        self.stats.merged_preads += 1;
        self.stats.merged_parts += parts;
        out
    }

    /// Touched a present/just-arrived page: fire async readahead if marked.
    fn maybe_async_trigger(
        &mut self,
        t: Time,
        id: FileId,
        p: u64,
        remaining: u64,
        st: &mut PreadStats,
        queued: bool,
    ) {
        if !self.files[id.0].slot(p).marker {
            return;
        }
        self.files[id.0].set_marker(p, false);
        if !self.ra_enabled {
            return;
        }
        if let Some(d) = ondemand_readahead(&self.files[id.0], self.ra_max_pages, p, remaining, true)
        {
            self.submit(t, id, &d, st, queued);
            self.stats.ra_async_windows += 1;
        }
    }

    /// Cache miss: synchronous readahead (or a plain windowless read).
    fn sync_fault(
        &mut self,
        t: Time,
        id: FileId,
        p: u64,
        remaining: u64,
        st: &mut PreadStats,
        queued: bool,
    ) {
        let decision = if self.ra_enabled {
            ondemand_readahead(&self.files[id.0], self.ra_max_pages, p, remaining, false)
        } else {
            None
        };
        match decision {
            Some(d) => {
                self.submit(t, id, &d, st, queued);
                self.stats.ra_windows += 1;
            }
            None => {
                // Random read: fetch exactly the absent run covering the
                // request, no window, no state update.
                let d = RaDecision {
                    start: p,
                    size: remaining,
                    marker: None,
                };
                self.submit_pages_only(t, id, &d, st, queued);
            }
        }
    }

    /// Submit a readahead decision: SSD command for the absent span, page
    /// flags, marker, and fd-state commit.
    fn submit(&mut self, t: Time, id: FileId, d: &RaDecision, st: &mut PreadStats, queued: bool) {
        self.submit_pages_only(t, id, d, st, queued);
        let f = &mut self.files[id.0];
        if let Some(m) = d.marker {
            if m < f.n_pages() {
                f.set_marker(m, true);
            }
        }
        let async_size = d.marker.map(|m| d.start + d.size - m).unwrap_or(0);
        f.ra.start = d.start;
        f.ra.size = d.size;
        f.ra.async_size = async_size;
    }

    fn submit_pages_only(
        &mut self,
        t: Time,
        id: FileId,
        d: &RaDecision,
        st: &mut PreadStats,
        queued: bool,
    ) {
        if let Some((start, len)) = absent_span(&self.files[id.0], d) {
            let ready = if queued {
                self.ssd.read_queued(t, len * OS_PAGE)
            } else {
                self.ssd.read(t, len * OS_PAGE)
            };
            for q in start..start + len {
                self.files[id.0].set_in_flight(q, ready);
            }
            st.ssd_cmds += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StackConfig;
    use crate::util::bytes::{gbps, GIB, KIB, MIB};

    fn vfs(ramfs: bool) -> Vfs {
        let c = StackConfig::k40c_p3700();
        Vfs::new(&c.ssd, &c.cpu, &c.readahead, ramfs)
    }

    /// One thread reading a file sequentially in `req`-byte preads;
    /// returns achieved bandwidth in GB/s.
    fn seq_read_bw(req: u64, total: u64) -> f64 {
        let mut v = vfs(false);
        let id = v.open(total);
        let mut now = 0;
        let mut off = 0;
        while off < total {
            let st = v.pread(now, id, off, req);
            now = st.done;
            off += req;
        }
        gbps(total, now)
    }

    #[test]
    fn sequential_4k_reads_engage_readahead() {
        let bw = seq_read_bw(4 * KIB, 64 * MIB);
        // Without readahead this would be ~0.04 GB/s (latency per page);
        // with async windows it must exceed 0.5 GB/s.
        assert!(bw > 0.5, "4K sequential: {bw} GB/s");
    }

    #[test]
    fn readahead_disabled_is_latency_bound() {
        let c = StackConfig::k40c_p3700();
        let ra_off = crate::config::ReadaheadConfig {
            enabled: false,
            ..c.readahead
        };
        let mut v = Vfs::new(&c.ssd, &c.cpu, &ra_off, false);
        let id = v.open(16 * MIB);
        let mut now = 0;
        let mut off = 0;
        while off < 16 * MIB {
            now = v.pread(now, id, off, 4 * KIB).done;
            off += 4 * KIB;
        }
        let bw = gbps(16 * MIB, now);
        assert!(bw < 0.08, "no-RA 4K sequential: {bw} GB/s");
    }

    #[test]
    fn oversize_requests_lose_pipelining() {
        // The paper's crossover: per-byte performance of 64K requests
        // (async tail alive) must beat 512K requests (async_size = 0).
        let bw_64k = seq_read_bw(64 * KIB, 256 * MIB);
        let bw_512k = seq_read_bw(512 * KIB, 256 * MIB);
        assert!(
            bw_64k > bw_512k,
            "64K={bw_64k} GB/s should beat 512K={bw_512k} GB/s"
        );
    }

    #[test]
    fn warm_cache_is_copy_bound() {
        let mut v = vfs(false);
        let id = v.open(8 * MIB);
        let mut now = 0;
        let mut off = 0;
        while off < 8 * MIB {
            now = v.pread(now, id, off, 64 * KIB).done;
            off += 64 * KIB;
        }
        // Second pass: all hits, no SSD.
        let cmds_before = v.stats.preads;
        let st = v.pread(now, id, 0, 64 * KIB);
        assert_eq!(st.hits, 16);
        assert_eq!(st.ssd_cmds, 0);
        assert!(st.done - now < 100_000);
        assert_eq!(v.stats.preads, cmds_before + 1);
    }

    #[test]
    fn interleaved_streams_all_pipeline() {
        // 8 interleaved 4K streams on ONE fd (the GPU host-thread pattern)
        // must sustain high bandwidth thanks to marker/context readahead.
        let mut v = vfs(false);
        let total = 128 * MIB;
        let id = v.open(total);
        let nstreams = 8u64;
        let stride = total / nstreams;
        let mut offs: Vec<u64> = (0..nstreams).map(|i| i * stride).collect();
        let mut now = 0;
        let mut moved = 0;
        'outer: loop {
            for s in 0..nstreams as usize {
                if offs[s] >= (s as u64 + 1) * stride {
                    break 'outer;
                }
                let st = v.pread(now, id, offs[s], 4 * KIB);
                now = st.done;
                offs[s] += 4 * KIB;
                moved += 4 * KIB;
            }
        }
        let bw = gbps(moved, now);
        assert!(bw > 0.5, "interleaved streams: {bw} GB/s");
    }

    #[test]
    fn interleaved_keeps_pace_with_sequential_for_small_reads() {
        // Fig 3's left half, in miniature: a consumer draining many
        // interleaved streams pipelines just as well as a strictly
        // sequential one — context readahead keeps every stream's window
        // advancing even though the fd's ra state is shared.  (The paper
        // measured interleaving as slightly *faster*; see EXPERIMENTS.md
        // §Deviations.)
        let interleaved = {
            let mut v = vfs(false);
            let total = 64 * MIB;
            let id = v.open(total);
            let n = 16u64;
            let stride = total / n;
            let mut offs: Vec<u64> = (0..n).map(|i| i * stride).collect();
            let mut now = 0;
            for _ in 0..(stride / (4 * KIB)) {
                for s in 0..n as usize {
                    let st = v.pread(now, id, offs[s], 4 * KIB);
                    now = st.done;
                    offs[s] += 4 * KIB;
                }
            }
            gbps(total, now)
        };
        let sequential = seq_read_bw(4 * KIB, 64 * MIB);
        assert!(
            interleaved > 0.85 * sequential,
            "interleaved {interleaved} vs sequential {sequential}"
        );
        assert!(interleaved > 0.5, "interleaved: {interleaved} GB/s");
    }

    #[test]
    fn ramfs_mode_never_touches_ssd() {
        let mut v = vfs(true);
        let id = v.open(GIB);
        let st = v.pread(0, id, 0, MIB);
        assert_eq!(st.ssd_cmds, 0);
        assert_eq!(v.ssd.commands(), 0);
        assert!(st.done > 0);
    }

    #[test]
    fn random_reads_fetch_only_requested() {
        let mut v = vfs(false);
        let id = v.open(GIB);
        // Far-apart random 4K reads: each is one miss, one 4K command.
        let mut now = 1;
        for i in 0..10u64 {
            let st = v.pread(now, id, (i * 97 + 11) * MIB, 4 * KIB);
            assert_eq!(st.ssd_cmds, 1);
            now = st.done;
        }
        assert_eq!(v.ssd.bytes_read(), 10 * 4 * KIB);
    }

    #[test]
    fn coalesced_pread_times_like_one_call_and_counts_the_merge() {
        let mut a = vfs(false);
        let mut b = vfs(false);
        let ia = a.open(GIB);
        let ib = b.open(GIB);
        // The union of three adjacent 64K requests costs exactly what one
        // 192K pread costs — that is the point of merging.
        let plain = a.pread(0, ia, MIB, 192 * KIB);
        let merged = b.pread_coalesced(0, ib, MIB, 192 * KIB, 3);
        assert_eq!(merged.done, plain.done);
        assert_eq!(merged.ssd_cmds, plain.ssd_cmds);
        assert_eq!(b.stats.merged_preads, 1);
        assert_eq!(b.stats.merged_parts, 3);
        assert_eq!(b.stats.preads, 1);
        assert_eq!(a.stats.merged_preads, 0);
    }

    #[test]
    fn submit_walk_is_nonblocking_and_io_lands_later() {
        let mut v = vfs(false);
        let id = v.open(64 * MIB);
        let (st, io) = v.pread_submit(0, id, 0, 64 * KIB);
        assert_eq!(st.blocked_ns, 0, "submit never blocks");
        assert!(st.ssd_cmds >= 1, "cold read must fault");
        assert!(
            st.done < 100_000,
            "submit cost is CPU-only, got {} ns",
            st.done
        );
        assert!(io > st.done, "cold data lands after the submit returns");
        // A warm rewalk is a pure hit: io_done collapses onto cpu done.
        let t = io + 1;
        let (st2, io2) = v.pread_submit(t, id, 0, 64 * KIB);
        assert_eq!(st2.ssd_cmds, 0);
        assert_eq!(io2, st2.done, "warm submit has nothing in flight");
    }

    #[test]
    fn deep_submit_window_beats_the_blocking_loop() {
        // The tentpole's sim acceptance shape: with 64K OS windows the
        // 20 µs per-command kernel gap is ~half the transfer time, so an
        // 8-deep submission window must beat the blocking loop by well
        // over 1.5× on a sequential scan.
        let c = StackConfig::k40c_p3700();
        let ra = crate::config::ReadaheadConfig {
            max_bytes: 64 * KIB,
            ..c.readahead
        };
        let total = 64 * MIB;
        let mut v = Vfs::new(&c.ssd, &c.cpu, &ra, false);
        let id = v.open(total);
        let (mut now, mut off) = (0, 0);
        while off < total {
            now = v.pread(now, id, off, 64 * KIB).done;
            off += 64 * KIB;
        }
        let bw_sync = gbps(total, now);

        let mut v = Vfs::new(&c.ssd, &c.cpu, &ra, false);
        let id = v.open(total);
        let mut inflight = std::collections::VecDeque::new();
        let (mut t, mut off) = (0, 0);
        while off < total {
            if inflight.len() >= 8 {
                let head: Time = inflight.pop_front().unwrap();
                t = t.max(head);
            }
            let (st, io) = v.pread_submit(t, id, off, 64 * KIB);
            t = st.done;
            inflight.push_back(io);
            off += 64 * KIB;
        }
        let end = inflight.into_iter().max().unwrap_or(0).max(t);
        let bw_async = gbps(total, end);
        assert!(
            bw_async > 1.5 * bw_sync,
            "window-8 {bw_async} GB/s vs blocking {bw_sync} GB/s"
        );
    }

    #[test]
    fn pread_clamps_at_eof() {
        let mut v = vfs(false);
        let id = v.open(10 * KIB);
        let st = v.pread(0, id, 8 * KIB, 64 * KIB);
        assert_eq!(st.pages, 1); // 8K..10K = one page
    }

    #[test]
    fn drop_caches_forgets_everything() {
        let mut v = vfs(false);
        let id = v.open(MIB);
        v.pread(0, id, 0, MIB);
        assert!(v.file(id).populated() > 0);
        v.drop_caches();
        assert_eq!(v.file(id).populated(), 0);
        assert_eq!(v.ssd.commands(), 0);
    }
}
