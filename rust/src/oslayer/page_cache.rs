//! CPU page cache: per-file 4 KiB page states.
//!
//! Pages are `Absent`, `InFlight` (an SSD read covering them has been
//! submitted; `ready` is its completion time), or `Present`.  A page may
//! carry the `PG_readahead` *marker*: touching a marked page is what
//! triggers asynchronous readahead of the next window (mm/readahead.c),
//! and because the marker lives on the page — not in per-thread state —
//! interleaved streams from many GPU threadblocks each keep their own
//! windows advancing.  That is the paper's "support of multiple strides
//! per file descriptor".

use crate::sim::Time;

/// OS page size: 4 KiB, independent of the GPUfs page size.
pub const OS_PAGE: u64 = 4096;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageState {
    Absent,
    InFlight,
    Present,
}

/// Compact per-page slot (16 bytes; a 10 GiB file is ~2.6 M slots).
#[derive(Debug, Clone, Copy)]
pub struct PageSlot {
    /// Completion time of the covering SSD read (valid when in flight).
    pub ready: Time,
    state: u8,
    /// PG_readahead marker.
    pub marker: bool,
}

impl PageSlot {
    const ABSENT: u8 = 0;
    const INFLIGHT: u8 = 1;
    const PRESENT: u8 = 2;

    #[inline]
    pub fn state(&self) -> PageState {
        match self.state {
            Self::ABSENT => PageState::Absent,
            Self::INFLIGHT => PageState::InFlight,
            _ => PageState::Present,
        }
    }
}

/// Identifier of an open file in the [`crate::oslayer::Vfs`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FileId(pub usize);

/// One cached file: page slots plus shared readahead state.
#[derive(Debug)]
pub struct CachedFile {
    pub size: u64,
    pages: Vec<PageSlot>,
    pub ra: crate::oslayer::readahead::RaState,
}

impl CachedFile {
    pub fn new(size: u64) -> Self {
        let n = size.div_ceil(OS_PAGE) as usize;
        CachedFile {
            size,
            pages: vec![
                PageSlot {
                    ready: 0,
                    state: PageSlot::ABSENT,
                    marker: false
                };
                n
            ],
            ra: Default::default(),
        }
    }

    #[inline]
    pub fn n_pages(&self) -> u64 {
        self.pages.len() as u64
    }

    #[inline]
    pub fn slot(&self, page: u64) -> &PageSlot {
        &self.pages[page as usize]
    }

    /// A read covering `page` completes at `ready`.
    #[inline]
    pub fn set_in_flight(&mut self, page: u64, ready: Time) {
        let s = &mut self.pages[page as usize];
        debug_assert_eq!(s.state, PageSlot::ABSENT, "page {page} double-submitted");
        s.state = PageSlot::INFLIGHT;
        s.ready = ready;
    }

    /// The simulated clock reached the page's I/O completion.
    #[inline]
    pub fn mark_present(&mut self, page: u64) {
        self.pages[page as usize].state = PageSlot::PRESENT;
    }

    #[inline]
    pub fn set_marker(&mut self, page: u64, on: bool) {
        self.pages[page as usize].marker = on;
    }

    /// Count Present/InFlight pages immediately before `page` (history run
    /// length, capped at `max`) — Linux's `count_history_pages`, the basis
    /// of context readahead for interleaved streams.
    pub fn history_run(&self, page: u64, max: u64) -> u64 {
        let mut n = 0;
        let mut p = page;
        while p > 0 && n < max {
            p -= 1;
            if self.pages[p as usize].state() == PageState::Absent {
                break;
            }
            n += 1;
        }
        n
    }

    /// First Absent page at or after `page` (readahead submit start).
    pub fn first_absent_from(&self, page: u64) -> Option<u64> {
        (page..self.n_pages())
            .find(|&p| self.pages[p as usize].state() == PageState::Absent)
    }

    /// Drop all cached pages + readahead state (echo 3 > drop_caches; the
    /// paper flushes the cache before every experiment).
    pub fn drop_caches(&mut self) {
        for s in &mut self.pages {
            *s = PageSlot {
                ready: 0,
                state: PageSlot::ABSENT,
                marker: false,
            };
        }
        self.ra = Default::default();
    }

    /// Number of present or in-flight pages (occupancy metric).
    pub fn populated(&self) -> u64 {
        self.pages
            .iter()
            .filter(|s| s.state() != PageState::Absent)
            .count() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sized_in_os_pages() {
        let f = CachedFile::new(10 * 4096 + 1);
        assert_eq!(f.n_pages(), 11);
        assert_eq!(f.slot(0).state(), PageState::Absent);
    }

    #[test]
    fn in_flight_then_present() {
        let mut f = CachedFile::new(8 * 4096);
        f.set_in_flight(3, 500);
        assert_eq!(f.slot(3).state(), PageState::InFlight);
        assert_eq!(f.slot(3).ready, 500);
        f.mark_present(3);
        assert_eq!(f.slot(3).state(), PageState::Present);
    }

    #[test]
    fn history_run_counts_backwards() {
        let mut f = CachedFile::new(16 * 4096);
        for p in 2..6 {
            f.set_in_flight(p, 0);
            f.mark_present(p);
        }
        assert_eq!(f.history_run(6, 32), 4);
        assert_eq!(f.history_run(6, 2), 2); // capped
        assert_eq!(f.history_run(2, 32), 0);
        assert_eq!(f.history_run(0, 32), 0);
    }

    #[test]
    fn first_absent_skips_populated() {
        let mut f = CachedFile::new(8 * 4096);
        f.set_in_flight(0, 0);
        f.set_in_flight(1, 0);
        assert_eq!(f.first_absent_from(0), Some(2));
        assert_eq!(f.first_absent_from(5), Some(5));
    }

    #[test]
    fn drop_caches_resets() {
        let mut f = CachedFile::new(4 * 4096);
        f.set_in_flight(1, 9);
        f.mark_present(1);
        f.set_marker(1, true);
        f.drop_caches();
        assert_eq!(f.slot(1).state(), PageState::Absent);
        assert!(!f.slot(1).marker);
        assert_eq!(f.populated(), 0);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn double_submit_is_a_bug() {
        let mut f = CachedFile::new(4 * 4096);
        f.set_in_flight(0, 1);
        f.set_in_flight(0, 2);
    }
}
