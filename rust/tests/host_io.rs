//! Acceptance claims for the asynchronous host I/O path (PR 7):
//!
//! * a deep submission window (`host.io_depth = 8`) lifts achieved SSD
//!   bandwidth >= 1.5x over the blocking loop on the sequential sweep
//!   row (the tentpole's sim acceptance), and no depth regresses the
//!   end-to-end numbers;
//! * the async path conserves bytes, requests, and the prefetch
//!   accounting laws — depth changes *when* data moves, never *what*;
//! * driven open-loop, a deep window delivers every stream's replies in
//!   per-stream submission order (the engine's per-thread FIFO), and the
//!   idle-with-inflight thread sleeps on `IoDone` instead of parking;
//! * at the storage seam, pooled completions that land out of submission
//!   order keep per-ticket slot identity — the property that makes FIFO
//!   reassembly (and therefore in-order grant delivery) possible at all.

use std::collections::HashMap;
use std::sync::OnceLock;

use gpufs_ra::config::StackConfig;
use gpufs_ra::experiments::fig_qd::{self, find, qd8_over_qd1, QdRow, DEPTHS};
use gpufs_ra::gpufs::host::{HostEngine, HostEvent};
use gpufs_ra::gpufs::rpc::Request;
use gpufs_ra::oslayer::{FileId, FileStorage, IoKind, IoReq, IoSlot, Storage};
use gpufs_ra::sim::{Calendar, Time};
use gpufs_ra::util::bytes::{GIB, KIB, MIB};
use gpufs_ra::workload::Microbench;

const SCALE: u64 = 16;

fn sweep() -> &'static Vec<QdRow> {
    static SWEEP: OnceLock<Vec<QdRow>> = OnceLock::new();
    SWEEP.get_or_init(|| fig_qd::run(&StackConfig::k40c_p3700(), SCALE).0)
}

#[test]
fn queue_depth_8_lifts_sequential_ssd_bandwidth_1_5x() {
    // 64 KiB OS readahead windows make the ~20 µs per-command kernel gap
    // about half of each command's flash transfer; an 8-deep window
    // overlaps those gaps (ssd.device_qd lanes) and must clear the
    // tentpole's acceptance ratio.
    let ratio = qd8_over_qd1(sweep(), "seq");
    assert!(
        ratio >= 1.5,
        "seq qd8/qd1 achieved SSD bandwidth {ratio:.3}x < 1.5x: {:?}",
        sweep()
            .iter()
            .filter(|r| r.workload == "seq")
            .map(|r| (r.io_depth, r.ssd_gbps))
            .collect::<Vec<_>>()
    );
    // Depth helps monotonically up to the device QD (8), modulo noise-free
    // sim arithmetic: each doubling up to 8 must not lose bandwidth.
    let seq = |d| find(sweep(), "seq", d).ssd_gbps;
    assert!(seq(2) >= seq(1) && seq(4) >= seq(2) && seq(8) >= seq(4));
    // Past the device QD there is nothing left to overlap: 16 never beats
    // 8 by another step change, and must not collapse either.
    assert!(seq(16) >= 0.95 * seq(8), "qd16 {} vs qd8 {}", seq(16), seq(8));
}

#[test]
fn no_depth_regresses_end_to_end_bandwidth() {
    for workload in ["seq", "cyc"] {
        let base = find(sweep(), workload, 1).gbps;
        for &d in &DEPTHS {
            let r = find(sweep(), workload, d);
            assert!(
                r.gbps >= 0.95 * base,
                "{workload} qd{d} end-to-end {} GB/s vs blocking {} GB/s",
                r.gbps,
                base
            );
        }
    }
}

#[test]
fn async_depth_conserves_bytes_requests_and_prefetch_laws() {
    let mut cfg = StackConfig::k40c_p3700();
    cfg.gpufs.prefetch_size = 32 * KIB;
    cfg.readahead.max_bytes = 64 * KIB;
    let m = Microbench::paper(4 * KIB).scaled(SCALE);
    let qd1 = gpufs_ra::experiments::run_micro(&cfg, &m);
    cfg.host.io_depth = 8;
    let qd8 = gpufs_ra::experiments::run_micro(&cfg, &m);
    assert_eq!(qd8.bytes, qd1.bytes, "every requested byte still arrives");
    assert_eq!(qd8.rpc.requests, qd1.rpc.requests);
    assert_eq!(
        qd8.prefetch.useful_bytes + qd8.prefetch.wasted_bytes,
        qd8.prefetch.prefetched_bytes,
        "prefetch conservation law broke under a deep window"
    );
    // The SSD reads each byte at most once plus readahead overshoot,
    // exactly like the blocking path.
    assert!(qd8.io.ssd_bytes <= m.total_bytes() + 8 * MIB, "ssd {}", qd8.io.ssd_bytes);
    // The whole point: the deep window finishes no later.
    assert!(
        qd8.end_ns <= qd1.end_ns,
        "qd8 end {} vs qd1 end {}",
        qd8.end_ns,
        qd1.end_ns
    );
}

// --------------------------------------------- open-loop engine drive

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Ev {
    Post(u32),
    Scan(u32),
}

/// Drive one single-threaded async HostEngine closed-loop: each of
/// `n_tbs` streams posts its next sequential request the instant the
/// previous reply lands (a threadblock has one outstanding gread, so
/// this is the real request discipline).  Returns each stream's reply
/// times in delivery order.
fn drive_streams(cfg: &StackConfig, n_tbs: u32, reads_per_tb: u64, io: u64) -> Vec<Vec<Time>> {
    let mut eng = HostEngine::new(cfg);
    eng.open(10 * GIB);
    let mut next_read = vec![0u64; n_tbs as usize];
    let mut replies: Vec<Vec<Time>> = vec![Vec::new(); n_tbs as usize];
    let mut cal: Calendar<Ev> = Calendar::new();
    for tb in 0..n_tbs {
        cal.schedule_at(tb as Time * 100, Ev::Post(tb));
    }
    cal.schedule_at(0, Ev::Scan(0));
    while let Some((now, ev)) = cal.pop() {
        match ev {
            Ev::Post(tb) => {
                let i = next_read[tb as usize];
                next_read[tb as usize] += 1;
                let req = Request {
                    tb,
                    file: FileId(0),
                    offset: tb as u64 * 64 * MIB + i * io,
                    demand_bytes: io,
                    prefetch_bytes: 0,
                    prefetch_back: false,
                    stream: None,
                    posted_at: now,
                    span: 0,
                };
                if let Some((th, wake)) = eng.post(req, now) {
                    cal.schedule_at(wake, Ev::Scan(th));
                }
            }
            Ev::Scan(t) => {
                for he in eng.scan(t, now, false, None) {
                    match he {
                        HostEvent::Reply { tb, at } => {
                            replies[tb as usize].push(at);
                            if (replies[tb as usize].len() as u64) < reads_per_tb {
                                cal.schedule_at(at.max(now), Ev::Post(tb));
                            }
                        }
                        HostEvent::Scan { thread, at } | HostEvent::IoDone { thread, at } => {
                            cal.schedule_at(at, Ev::Scan(thread));
                        }
                        HostEvent::Stage { .. } => {
                            unreachable!("overlap staging is off in this drive")
                        }
                    }
                }
            }
        }
    }
    replies
}

#[test]
fn deep_window_delivers_every_stream_and_terminates() {
    // One host thread, eight streams, window of four: the thread keeps
    // up to four preads in flight across streams, sleeps on IoDone when
    // its queue runs dry (instead of parking with data still in flight),
    // and must hand every stream all of its grants — none lost, none
    // duplicated, each stream's reply times strictly advancing.  A FIFO
    // delivery bug (delivering a younger in-flight group's reply to an
    // older group's still-blocked poster) shows up here as a stuck
    // calendar or a short reply log.
    let mut cfg = StackConfig::k40c_p3700();
    cfg.gpufs.host_threads = 1;
    cfg.gpufs.page_size = 64 * KIB;
    cfg.host.io_depth = 4;
    cfg.no_pcie = true;
    let (n_tbs, per_tb) = (8u32, 6u64);
    let replies = drive_streams(&cfg, n_tbs, per_tb, 64 * KIB);
    for (tb, log) in replies.iter().enumerate() {
        assert_eq!(log.len(), per_tb as usize, "tb{tb} lost replies: {log:?}");
        for w in log.windows(2) {
            assert!(w[1] > w[0], "tb{tb} replies did not advance: {log:?}");
        }
    }
}

// --------------------------------------------------- storage-seam OOO

#[test]
fn pooled_out_of_order_completions_keep_per_stream_identity() {
    // Two interleaved streams over a width-4 reader pool, request sizes
    // chosen so completions race: whatever order the pool lands them in,
    // every ticket carries its own slots, so sorting a stream's
    // completions by ticket reconstructs it exactly — the invariant the
    // host engine's per-thread FIFO delivery rests on.
    let data: Vec<u8> = (0..512 * 1024u32).map(|i| (i % 239) as u8).collect();
    let p = std::env::temp_dir().join("gpufs_ra_host_io_ooo.bin");
    std::fs::write(&p, &data).unwrap();
    let mut s = FileStorage::open(std::slice::from_ref(&p)).unwrap();
    s.spawn_pool(4).unwrap();

    // Stream A: large contiguous reads from the front half; stream B:
    // small per-page reads from the back half.
    let mut expect: HashMap<u64, (usize, u64, u64)> = HashMap::new(); // ticket -> (stream, off, len)
    for i in 0..6u64 {
        let (off, len) = (i * 32 * 1024, 32 * 1024u64);
        let sub = s
            .submit(
                0,
                IoReq {
                    id: FileId(0),
                    kind: IoKind::Contig { parts: 1 },
                    slots: vec![IoSlot {
                        offset: off,
                        len,
                        buf: Some(vec![0u8; len as usize]),
                    }],
                },
            )
            .unwrap();
        expect.insert(sub.ticket, (0, off, len));
        let (off, len) = (256 * 1024 + i * 4096, 4096u64);
        let sub = s
            .submit(
                0,
                IoReq {
                    id: FileId(0),
                    kind: IoKind::PerPage,
                    slots: vec![IoSlot {
                        offset: off,
                        len,
                        buf: Some(vec![0u8; len as usize]),
                    }],
                },
            )
            .unwrap();
        expect.insert(sub.ticket, (1, off, len));
    }

    let mut done = Vec::new();
    while done.len() < expect.len() {
        let batch = s.complete_blocking(1).unwrap();
        assert!(!batch.is_empty(), "pool went quiet with submissions in flight");
        done.extend(batch);
    }
    assert_eq!(s.in_flight(), 0);
    for d in &done {
        assert!(d.error.is_none(), "{:?}", d.error);
        let (_, off, len) = expect[&d.ticket];
        assert_eq!(d.slots[0].offset, off, "ticket {} lost its slot", d.ticket);
        assert_eq!(
            d.slots[0].buf.as_ref().unwrap()[..],
            data[off as usize..(off + len) as usize],
            "ticket {} carries another request's bytes",
            d.ticket
        );
    }
    // Reassemble each stream FIFO (by ticket, i.e. submission order) out
    // of whatever arrival order the pool produced: the concatenation must
    // be the stream's exact byte range — in-order grant delivery is
    // recoverable from the scrambled completion stream.
    done.sort_unstable_by_key(|d| d.ticket);
    for (stream, base, total) in [(0usize, 0usize, 192 * 1024usize), (1, 256 * 1024, 24 * 1024)] {
        let mut assembled = Vec::with_capacity(total);
        for d in done.iter().filter(|d| expect[&d.ticket].0 == stream) {
            assembled.extend_from_slice(d.slots[0].buf.as_ref().unwrap());
        }
        assert_eq!(
            assembled,
            data[base..base + total],
            "stream {stream} did not reassemble in submission order"
        );
    }
    let _ = std::fs::remove_file(p);
}
