//! `buffer_slots = 1` regression anchor for the per-stream buffer pool.
//!
//! The pool refactor must be behaviour-preserving at one slot: the
//! adaptive engine's decision trace (every per-miss grant, in order) and
//! the prefetch accounting (buffer hits, useful / wasted / prefetched
//! bytes) must be byte-identical to the pre-refactor single-range
//! private buffer.  Since that implementation is gone from the tree, a
//! verbatim copy of it (StreamTable with the internal granted/filling
//! feedback rotation + the single-range PrivateBuffer) lives here, and
//! both stacks are driven through the same gread-miss cadence the
//! simulator produces, over every access shape the fig_adaptive
//! experiment sweeps plus randomized mixtures.
//!
//! Known, deliberate exception (not exercised here because the old
//! behaviour was a documented wart): when the stream that earned the
//! in-buffer fill has been LRU-evicted from the table before the fill is
//! displaced, the legacy code charged the waste to whichever stream
//! inherited the table slot; the pool charges it to nobody.

use gpufs_ra::config::StackConfig;
use gpufs_ra::gpufs::prefetcher::{Advice, BufferPool, TbReadahead};
use gpufs_ra::oslayer::FileId;
use gpufs_ra::readahead::StreamId;
use gpufs_ra::util::prng::Prng;

const PS: u64 = 4096;
const BIG: u64 = 1 << 40;

/// Verbatim pre-refactor implementation (PR 1 state of
/// `rust/src/readahead/stream.rs` + `rust/src/gpufs/prefetcher.rs`).
mod legacy {
    use gpufs_ra::config::GpufsConfig;
    use gpufs_ra::gpufs::prefetcher::Advice;
    use gpufs_ra::oslayer::FileId;
    use gpufs_ra::readahead::RaPolicy;

    #[derive(Debug, Clone, Copy)]
    struct StreamSlot {
        key: u64,
        last: u64,
        stride: u64,
        expect: u64,
        window: u64,
        hold: bool,
        dark: bool,
        age: u64,
    }

    #[derive(Debug, Clone)]
    pub struct StreamTable {
        slots: Vec<StreamSlot>,
        cap: usize,
        tick: u64,
        granted: Option<usize>,
        filling: Option<usize>,
    }

    const SPARSE_STRIDE_MUL: u64 = 2;
    const MAX_JUMP_WINDOWS: u64 = 8;

    impl StreamTable {
        pub fn new(cap: usize) -> StreamTable {
            StreamTable {
                slots: Vec::with_capacity(cap.max(1)),
                cap: cap.max(1),
                tick: 0,
                granted: None,
                filling: None,
            }
        }

        pub fn observe(&mut self, policy: &RaPolicy, key: u64, pos: u64, demand: u64) -> u64 {
            self.tick += 1;
            let demand = demand.max(1);

            if let Some(i) = self
                .slots
                .iter()
                .position(|s| s.key == key && s.expect == pos)
            {
                let tick = self.tick;
                let s = &mut self.slots[i];
                let stride = if s.stride == 0 { demand } else { s.stride };
                if s.dark || stride > demand.saturating_mul(SPARSE_STRIDE_MUL) {
                    s.last = pos;
                    s.expect = pos + stride.max(demand);
                    s.age = tick;
                    return 0;
                }
                s.window = if s.window == 0 {
                    policy.init_window(demand).min(policy.max)
                } else if s.hold {
                    s.hold = false;
                    s.window
                } else {
                    policy.next_window(s.window)
                };
                let grant = s.window;
                s.last = pos;
                s.expect = next_expected(pos, demand, grant, stride);
                s.age = tick;
                if grant > 0 {
                    self.granted = Some(i);
                }
                return grant;
            }

            let max_jump = policy.max.max(demand).saturating_mul(MAX_JUMP_WINDOWS);
            let mut best: Option<(usize, u64)> = None;
            for (i, s) in self.slots.iter().enumerate() {
                if s.key == key && pos > s.last {
                    let d = pos - s.last;
                    if d <= max_jump && best.map(|(_, bd)| d < bd).unwrap_or(true) {
                        best = Some((i, d));
                    }
                }
            }
            if let Some((i, d)) = best {
                let tick = self.tick;
                let s = &mut self.slots[i];
                if d != s.stride {
                    s.dark = false;
                }
                s.stride = d;
                s.window = policy.shrink(s.window);
                s.hold = false;
                s.last = pos;
                s.expect = pos + d.max(demand);
                s.age = tick;
                return 0;
            }

            let slot = StreamSlot {
                key,
                last: pos,
                stride: 0,
                expect: pos + demand,
                window: 0,
                hold: false,
                dark: false,
                age: self.tick,
            };
            if self.slots.len() < self.cap {
                self.slots.push(slot);
            } else {
                let lru = self
                    .slots
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, s)| s.age)
                    .map(|(i, _)| i)
                    .unwrap();
                self.slots[lru] = slot;
            }
            0
        }

        pub fn feedback_waste(&mut self, policy: &RaPolicy, unused: u64, filled: u64) {
            let replaced = self.filling;
            self.filling = self.granted.take();
            if unused == 0 || filled == 0 {
                return;
            }
            if let Some(i) = replaced {
                if let Some(s) = self.slots.get_mut(i) {
                    if unused >= filled {
                        s.window = 0;
                        s.hold = false;
                        s.dark = true;
                    } else if unused.saturating_mul(2) >= filled {
                        s.window = policy.shrink(s.window);
                        s.hold = true;
                    }
                }
            }
        }
    }

    fn next_expected(pos: u64, demand: u64, grant: u64, stride: u64) -> u64 {
        let covered = demand + grant;
        if stride <= demand {
            return pos + covered;
        }
        let k = covered.div_ceil(stride).max(1);
        pos + k * stride
    }

    #[derive(Debug, Clone, Copy, Default)]
    pub struct PrivateBuffer {
        range: Option<(FileId, u64, u64)>,
    }

    impl PrivateBuffer {
        #[inline]
        pub fn covers(&self, file: FileId, offset: u64, page_size: u64) -> bool {
            match self.range {
                Some((f, s, e)) => f == file && offset >= s && offset + page_size <= e,
                None => false,
            }
        }

        #[inline]
        pub fn fill(&mut self, file: FileId, start: u64, end: u64) {
            debug_assert!(start < end);
            self.range = Some((file, start, end));
        }

        pub fn clear(&mut self) {
            self.range = None;
        }

        pub fn len(&self) -> u64 {
            self.range.map(|(_, s, e)| e - s).unwrap_or(0)
        }
    }

    const STREAMS_PER_TB: usize = 4;

    #[derive(Debug, Clone)]
    pub struct TbReadahead {
        policy: RaPolicy,
        streams: StreamTable,
        page_size: u64,
    }

    impl TbReadahead {
        pub fn new(g: &GpufsConfig) -> TbReadahead {
            let ps = g.page_size;
            let ramp = g.ra_ramp.max(2);
            TbReadahead {
                policy: RaPolicy {
                    max: (g.ra_max / ps).max(1),
                    min: g.ra_min / ps,
                    init_quad_div: 32,
                    init_double_div: 4,
                    ramp_fast_div: 16,
                    ramp_fast_mul: ramp.saturating_mul(2),
                    ramp_slow_mul: ramp,
                    shrink_div: 2,
                },
                streams: StreamTable::new(STREAMS_PER_TB),
                page_size: ps,
            }
        }

        pub fn prefetch_bytes(
            &mut self,
            read_only: bool,
            advice: Advice,
            file: FileId,
            offset: u64,
            demand_bytes: u64,
            file_size: u64,
        ) -> u64 {
            if !read_only || advice == Advice::Random {
                return 0;
            }
            let ps = self.page_size;
            let page = offset / ps;
            let demand_pages = demand_bytes.div_ceil(ps).max(1);
            let grant = self
                .streams
                .observe(&self.policy, file.0 as u64, page, demand_pages);
            let after_demand = (offset + demand_bytes).min(file_size);
            (file_size - after_demand).min(grant * ps)
        }

        pub fn feedback_waste(&mut self, unused_bytes: u64, filled_bytes: u64) {
            self.streams
                .feedback_waste(&self.policy, unused_bytes, filled_bytes);
        }
    }
}

/// One simulated gread access: (file, byte offset of the missing page).
type Access = (usize, u64);

/// The prefetch-visible outcome of a drive: per-miss grants in order
/// (the decision trace) plus the `PrefetchStats` fields the buffer
/// affects.
#[derive(Debug, Default, PartialEq, Eq)]
struct Outcome {
    grants: Vec<u64>,
    buffer_hits: u64,
    useful_bytes: u64,
    wasted_bytes: u64,
    prefetched_bytes: u64,
}

/// Drive the post-refactor stack (pool with the configured slot count)
/// through `accesses`, replicating the simulator's prefetch cadence.
fn drive_pool(accesses: &[Access], file_size: u64, slots: u32) -> Outcome {
    let mut g = StackConfig::k40c_p3700().gpufs;
    g.buffer_slots = slots;
    let mut ra = TbReadahead::new(&g);
    let mut pool = BufferPool::new(g.buffer_slots);
    let mut out = Outcome::default();
    for &(f, off) in accesses {
        let file = FileId(f);
        if let Some(i) = pool.probe(file, off, PS) {
            pool.consume(i, PS);
            out.buffer_hits += 1;
            out.useful_bytes += PS;
            continue;
        }
        let (pf, _back, stream): (u64, bool, Option<StreamId>) =
            ra.prefetch_bytes(true, Advice::Normal, file, off, PS, file_size);
        out.grants.push(pf);
        if pf > 0 {
            let start = off + PS;
            let replaced = pool.fill(file, start, start + pf, stream);
            if let Some(owner) = replaced.owner {
                ra.feedback_waste(owner, replaced.unused, replaced.filled);
            }
            out.wasted_bytes += replaced.unused;
            out.prefetched_bytes += pf;
        }
    }
    out.wasted_bytes += pool.abandon();
    out
}

/// Drive the pre-refactor stack (verbatim legacy copy) through the same
/// accesses with the same cadence.
fn drive_legacy(accesses: &[Access], file_size: u64) -> Outcome {
    let g = StackConfig::k40c_p3700().gpufs;
    let mut ra = legacy::TbReadahead::new(&g);
    let mut buf = legacy::PrivateBuffer::default();
    let mut consumed = 0u64;
    let mut out = Outcome::default();
    for &(f, off) in accesses {
        let file = FileId(f);
        if buf.covers(file, off, PS) {
            consumed += PS;
            out.buffer_hits += 1;
            out.useful_bytes += PS;
            continue;
        }
        let pf = ra.prefetch_bytes(true, Advice::Normal, file, off, PS, file_size);
        out.grants.push(pf);
        if pf > 0 {
            let filled = buf.len();
            let unused = filled.saturating_sub(consumed);
            ra.feedback_waste(unused, filled);
            out.wasted_bytes += unused;
            out.prefetched_bytes += pf;
            let start = off + PS;
            buf.fill(file, start, start + pf);
            consumed = 0;
        }
    }
    out.wasted_bytes += buf.len().saturating_sub(consumed);
    buf.clear();
    out
}

fn assert_equivalent(name: &str, accesses: &[Access], file_size: u64) {
    let new = drive_pool(accesses, file_size, 1);
    let old = drive_legacy(accesses, file_size);
    assert_eq!(
        new, old,
        "{name}: slots=1 pool diverged from the legacy single-range buffer"
    );
    // Conservation sanity on both: every prefetched byte is either
    // consumed or charged as waste by the end.
    assert_eq!(new.useful_bytes + new.wasted_bytes, new.prefetched_bytes);
}

// ----------------------------------------------------- access shapes

fn sequential(file: usize, base: u64, pages: u64) -> Vec<Access> {
    (0..pages).map(|p| (file, base + p * PS)).collect()
}

fn strided(file: usize, base: u64, stride_pages: u64, n: u64) -> Vec<Access> {
    (0..n).map(|k| (file, base + k * stride_pages * PS)).collect()
}

fn round_robin(lanes: &[Vec<Access>]) -> Vec<Access> {
    let len = lanes.iter().map(|l| l.len()).min().unwrap_or(0);
    let mut out = Vec::with_capacity(len * lanes.len());
    for i in 0..len {
        for lane in lanes {
            out.push(lane[i]);
        }
    }
    out
}

#[test]
fn sequential_stream_is_equivalent() {
    assert_equivalent("sequential", &sequential(0, 0, 2000), BIG);
}

#[test]
fn sequential_stream_at_eof_is_equivalent() {
    // The file ends mid-ramp: EOF clamping and the abandoned final fill
    // must account identically.
    for pages in [1u64, 7, 60, 300] {
        let accesses = sequential(0, 0, pages);
        assert_equivalent("sequential@eof", &accesses, pages * PS);
    }
}

#[test]
fn dense_and_sparse_strides_are_equivalent() {
    assert_equivalent("stride2", &strided(0, 0, 2, 800), BIG);
    assert_equivalent("stride8-sparse", &strided(0, 0, 8, 800), BIG);
}

#[test]
fn interleaved_lanes_thrash_identically() {
    // The pattern the pool exists for: with one slot both stacks must
    // waste the same fills, send the same streams dark, and settle at
    // the same demand-only cadence.
    for ways in [2usize, 3, 4] {
        let lanes: Vec<Vec<Access>> = (0..ways)
            .map(|w| sequential(0, w as u64 * (1 << 30), 600))
            .collect();
        let accesses = round_robin(&lanes);
        let name = format!("interleaved-{ways}");
        assert_equivalent(&name, &accesses, BIG);
    }
}

#[test]
fn two_files_are_equivalent() {
    let lanes = vec![sequential(0, 0, 500), sequential(1, 0, 500)];
    assert_equivalent("two-files", &round_robin(&lanes), BIG);
}

#[test]
fn random_access_is_equivalent() {
    // Strictly-forward far jumps (every step well past the re-sync
    // reach): a fresh stream per miss, constant LRU churn, no grants —
    // on either side.
    let mut rng = Prng::new(0xB0F4);
    let mut accesses = Vec::new();
    let mut pos = 0u64;
    for _ in 0..800 {
        accesses.push((0usize, pos * PS));
        pos += 1_000 + rng.gen_range(1 << 20);
    }
    assert_equivalent("random", &accesses, 1 << 42);
}

#[test]
fn randomized_walker_mixtures_are_equivalent() {
    // 3 sequential walkers in random interleavings with occasional
    // in-lane forward jumps.  Jumps are 26..=125 pages: always past the
    // current fill (so the next access is a miss) yet within the
    // re-sync reach, so they shrink windows and cause partial waste
    // without ever spawning fresh streams.  The table therefore never
    // LRU-evicts a fill-owning stream — the one corner where the pool
    // deliberately improves on the legacy behaviour (see module doc).
    for seed in [1u64, 2, 3, 0xDEAD, 0xBEEF] {
        let mut rng = Prng::new(seed);
        let mut cursors = [0u64, 1 << 30, 1 << 31];
        let mut accesses = Vec::new();
        for _round in 0..80 {
            // Visit every walker once per round, in a rotating order,
            // with a random burst length each.
            let rot = rng.gen_range(3) as usize;
            for i in 0..3 {
                let w = (i + rot) % 3;
                let burst = 1 + rng.gen_range(6);
                for _ in 0..burst {
                    accesses.push((0usize, cursors[w]));
                    cursors[w] += PS;
                }
                if rng.gen_range(4) == 0 {
                    cursors[w] += (26 + rng.gen_range(100)) * PS;
                }
            }
        }
        assert_equivalent(&format!("mixture-seed-{seed}"), &accesses, BIG);
    }
}
