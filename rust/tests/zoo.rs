//! Workload-zoo acceptance bands (backward-stream + burst-window
//! readahead):
//!
//! * adaptive + `ra_backward` + `ra_burst` ("zoo") delivers >= 1.5x the
//!   prefetch-off bandwidth on the Parquet shape, forward AND backward
//!   row-group order, and does not lose to plain adaptive there;
//! * on the ML-epoch shape the page cache — not the prefetcher —
//!   carries epoch 2: hit rate >= 0.9 when the working set fits,
//!   strictly worse when the cache holds only half of it;
//! * backward streams work end-to-end: windows are granted BELOW the
//!   demand position, consumed out of the private buffer, and the
//!   sign-agnostic waste accounting keeps the prefetch conservation
//!   law exact;
//! * both knobs default off and, even when ON, leave forward
//!   sequential/strided streams event-identical — the zoo is pay-as-
//!   you-go.

use gpufs_ra::config::StackConfig;
use gpufs_ra::experiments::fig_zoo;
use gpufs_ra::gpufs::{FileSpec, GpufsSim, Gread, TbProgram};
use gpufs_ra::oslayer::FileId;
use gpufs_ra::util::bytes::{KIB, MIB};
use gpufs_ra::workload::{EpochBench, Microbench, ParquetBench, StridedBench};

fn cfg() -> StackConfig {
    StackConfig::k40c_p3700()
}

/// Paper-shape Parquet bands (full 16 row groups, so burst locking has
/// room to amortize its two measuring chunks) at a test-sized
/// threadblock count.
fn parquet(backward: bool) -> ParquetBench {
    let mut p = ParquetBench::paper(4 * KIB, backward);
    p.n_tbs = 24;
    p
}

#[test]
fn zoo_lifts_parquet_1_5x_over_prefetch_off_both_orders() {
    let cfg = cfg();
    for backward in [false, true] {
        let p = parquet(backward);
        let g = fig_zoo::sweep(&cfg, &p.files(), &p.programs(), cfg.gpufs.cache_size);
        let (off, adaptive, zoo) = (g[0], g[2], g[3]);
        let order = if backward { "bwd" } else { "fwd" };
        assert!(
            zoo >= 1.5 * off,
            "parquet_{order}: zoo {zoo:.3} GB/s < 1.5x prefetch-off {off:.3} GB/s \
             (sweep {g:?})"
        );
        // The burst detector must at least pay for itself vs the stock
        // adaptive windows on its target pattern.
        assert!(
            zoo >= adaptive,
            "parquet_{order}: zoo {zoo:.3} GB/s lost to plain adaptive {adaptive:.3} GB/s"
        );
    }
}

/// Epoch-2 hit rate by differencing a 1-epoch and a 2-epoch run (the
/// epoch-1 access stream is identical, per-tb regions disjoint, so the
/// counter delta is exactly the second epoch).
fn epoch2_hit_rate(cfg: &StackConfig, e: &EpochBench, cache: u64) -> f64 {
    let c = fig_zoo::variant_cfg(cfg, 3, cache);
    let mut one = e.clone();
    one.epochs = 1;
    let r1 = GpufsSim::new(&c, one.files(), one.programs(), 512).run();
    let r2 = GpufsSim::new(&c, e.files(), e.programs(), 512).run();
    let lookups = r2.cache.lookups.saturating_sub(r1.cache.lookups);
    let hits = r2.cache.hits.saturating_sub(r1.cache.hits);
    assert!(lookups > 0, "epoch 2 produced no cache traffic");
    hits as f64 / lookups as f64
}

#[test]
fn epoch_two_is_carried_by_the_cache_when_the_working_set_fits() {
    let cfg = cfg();
    let mut e = EpochBench::paper(2);
    e.n_tbs = 24; // 96 MiB working set
    let ws = e.working_set();
    let fit = epoch2_hit_rate(&cfg, &e, 2 * ws);
    assert!(
        fit >= 0.9,
        "epoch-2 hit rate {fit:.3} < 0.9 with the working set fitting the cache"
    );
    // Halve the cache below the working set: epoch 2 cannot be carried.
    let thrash = epoch2_hit_rate(&cfg, &e, ws / 2);
    assert!(
        thrash < fit,
        "thrash-regime hit rate {thrash:.3} not below fit-regime {fit:.3}"
    );
}

/// `n_tbs` threadblocks each scanning their own `region` in strictly
/// DESCENDING `io`-byte reads — the access pattern `ra_backward` exists
/// for.
fn descending(n_tbs: u32, region: u64, io: u64) -> (Vec<FileSpec>, Vec<TbProgram>) {
    let files = vec![FileSpec::read_only(n_tbs as u64 * region)];
    let programs = (0..n_tbs)
        .map(|tb| {
            let base = tb as u64 * region;
            TbProgram {
                reads: (0..region / io)
                    .map(|i| Gread {
                        file: FileId(0),
                        offset: base + region - (i + 1) * io,
                        len: io,
                    })
                    .collect(),
                compute_ns_per_read: 0,
                rmw: false,
            }
        })
        .collect();
    (files, programs)
}

#[test]
fn backward_streams_prefetch_below_the_demand_end_to_end() {
    let cfg = cfg();
    let (files, programs) = descending(8, 4 * MIB, 4 * KIB);
    let run = |variant: usize| {
        let c = fig_zoo::variant_cfg(&cfg, variant, cfg.gpufs.cache_size);
        GpufsSim::new(&c, files.clone(), programs.clone(), 512)
            .with_grant_log()
            .run()
    };
    let off = run(0);
    let plain = run(2);
    let zoo = run(3);
    assert_eq!(off.prefetch.prefetched_bytes, 0);
    // Without the knob, no grant is ever backward.
    assert!(
        plain.grants.iter().flatten().all(|g| !g.back),
        "plain adaptive emitted a backward grant with ra_backward off"
    );
    // With it, descending scans earn windows below the demand — and the
    // threadblocks actually consume them out of the private buffer.
    let back_grants = zoo.grants.iter().flatten().filter(|g| g.back).count();
    assert!(back_grants > 0, "no backward grants on a descending scan");
    assert!(
        zoo.grants.iter().flatten().all(|g| g.prefetch > 0 || !g.back),
        "a zero-byte grant must not be flagged backward"
    );
    let reads = 8 * (4 * MIB / (4 * KIB));
    assert!(
        zoo.prefetch.buffer_hits > reads / 2,
        "backward windows granted but not consumed: {} hits of {} reads",
        zoo.prefetch.buffer_hits,
        reads
    );
    // Satellite: sign-agnostic waste feedback keeps the conservation
    // law exact for backward fills too.
    assert_eq!(
        zoo.prefetch.useful_bytes + zoo.prefetch.wasted_bytes,
        zoo.prefetch.prefetched_bytes,
        "prefetch conservation law broke on backward grants"
    );
    assert!(zoo.bytes == off.bytes, "every demanded byte still arrives");
    assert!(
        zoo.bandwidth >= 1.2 * off.bandwidth,
        "backward readahead {:.3} GB/s < 1.2x prefetch-off {:.3} GB/s",
        zoo.bandwidth,
        off.bandwidth
    );
}

#[test]
fn zoo_knobs_leave_forward_streams_event_identical() {
    let cfg = cfg();
    let grants = |files: Vec<FileSpec>, programs: Vec<TbProgram>, variant: usize| {
        let c = fig_zoo::variant_cfg(&cfg, variant, cfg.gpufs.cache_size);
        GpufsSim::new(&c, files, programs, 512)
            .with_grant_log()
            .run()
            .grants
    };
    // Sequential and forward-strided streams never jump past the
    // adaptive window, so the backward/burst branches must never fire:
    // the request/grant streams are bit-identical with the knobs ON.
    let m = Microbench::paper(4 * KIB).scaled(64);
    assert_eq!(
        grants(m.files(), m.programs(), 2),
        grants(m.files(), m.programs(), 3),
        "zoo knobs perturbed the sequential grant stream"
    );
    let s = StridedBench::paper(4 * KIB, 32 * KIB).scaled(64);
    assert_eq!(
        grants(s.files(), s.programs(), 2),
        grants(s.files(), s.programs(), 3),
        "zoo knobs perturbed the strided grant stream"
    );
}

#[test]
fn fig_zoo_rows_are_well_formed_at_small_scale() {
    let (rows, t) = fig_zoo::run(&cfg(), 16);
    assert_eq!(rows.len(), 4);
    assert_eq!(
        rows.iter().map(|r| r.workload).collect::<Vec<_>>(),
        vec!["parquet_fwd", "parquet_bwd", "epoch_fit", "epoch_thrash"]
    );
    for r in &rows {
        for (v, g) in fig_zoo::VARIANTS.iter().zip(r.gbps) {
            assert!(g.is_finite() && g > 0.0, "{}/{v}: bad bandwidth {g}", r.workload);
        }
    }
    for r in &rows[..2] {
        assert!(r.epoch2_hit_rate.is_nan(), "parquet rows carry no hit rate");
    }
    for r in &rows[2..] {
        assert!(
            (0.0..=1.0).contains(&r.epoch2_hit_rate),
            "{}: hit rate {} outside [0,1]",
            r.workload,
            r.epoch2_hit_rate
        );
    }
    assert!(t.render().contains("epoch2_hit_rate"));
}
