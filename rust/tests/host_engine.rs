//! Acceptance claims for the HostEngine knobs (ISSUE 3), shared over one
//! `fig_host` sweep at reduced scale (OnceLock, like the fig_adaptive
//! suite):
//!
//! * `rpc_dispatch = steal` drives every host thread's
//!   `spins_before_first` to ~0 in the first occupancy wave (the Fig 6
//!   pathology, resolved) and cuts the worst queueing delay;
//! * `host_coalesce = adjacent` merges the block-cyclic workload's poll
//!   batches into large preads — far fewer pread calls, fewer/larger SSD
//!   commands, higher achieved SSD bandwidth;
//! * `host_overlap = on` shortens end-to-end time where pread and
//!   staging+DMA costs are comparable (the RAMfs two-thread row);
//! * no knob combination regresses the sequential single-stream row.

use std::sync::OnceLock;

use gpufs_ra::config::{HostCoalesce, RpcDispatch, StackConfig};
use gpufs_ra::experiments::fig_host::{self, find, FigHostRow, COMBOS};
use gpufs_ra::util::bytes::{KIB, MIB};
use gpufs_ra::workload::{BlockCyclicBench, Microbench};

const SCALE: u64 = 16;

fn sweep() -> &'static Vec<FigHostRow> {
    static SWEEP: OnceLock<Vec<FigHostRow>> = OnceLock::new();
    SWEEP.get_or_init(|| fig_host::run(&StackConfig::k40c_p3700(), SCALE).0)
}

fn base(workload: &str) -> &'static FigHostRow {
    find(sweep(), workload, RpcDispatch::Static, HostCoalesce::Off, false)
}

#[test]
fn steal_dispatch_resolves_the_fig6_first_wave_starvation() {
    let static_row = base("seq_64k");
    let steal = find(
        sweep(),
        "seq_64k",
        RpcDispatch::Steal,
        HostCoalesce::Off,
        false,
    );
    // Static reproduces the pathology: threads 2,3 spin for the whole
    // first wave...
    assert!(
        static_row.max_spins_before_first() > 500,
        "static first-wave starvation vanished: {:?}",
        static_row.spins
    );
    // ...steal erases it for EVERY thread.
    assert!(
        steal.max_spins_before_first() < 100,
        "steal left a thread starving: {:?}",
        steal.spins
    );
    assert!(steal.stolen > 0, "steal dispatch never stole");
    // No request waits on a busy owner while another thread idles, so the
    // worst queueing delay cannot get worse.
    assert!(
        steal.qd_max_us <= static_row.qd_max_us,
        "steal worst-case queue delay {} vs static {}",
        steal.qd_max_us,
        static_row.qd_max_us
    );
    assert!(steal.gbps >= 0.95 * static_row.gbps);
}

#[test]
fn adjacent_coalescing_merges_block_cyclic_preads() {
    let off = base("blockcyclic_4k");
    let adj = find(
        sweep(),
        "blockcyclic_4k",
        RpcDispatch::Static,
        HostCoalesce::Adjacent,
        false,
    );
    assert!(adj.merged_preads > 0, "no pread was ever coalesced");
    assert!(adj.merged > 0);
    assert!(
        adj.preads * 4 <= off.preads,
        "coalescing should cut pread calls >=4x: {} vs {}",
        adj.preads,
        off.preads
    );
    // Off is DMA-setup-bound (one 4K DMA per request, the GPUfs-4K
    // calibration point); merged groups pread once and ride page-batched
    // DMAs, so the SSD finally gets fed (the paper's §3 request-size
    // logic applied host-side).
    assert!(
        adj.ssd_gbps > 1.5 * off.ssd_gbps,
        "achieved ssd bw {} vs {}",
        adj.ssd_gbps,
        off.ssd_gbps
    );
    assert!(
        adj.gbps > 1.5 * off.gbps,
        "end-to-end {} vs {}",
        adj.gbps,
        off.gbps
    );
}

#[test]
fn overlap_shortens_host_bound_runs() {
    // RAMfs + two host threads: per-request pread (~16 µs of page
    // walking) vs staging+DMA (~26 µs + 15 µs) — comparable, and the
    // host thread is the bottleneck, so the staging pipeline shows.
    let off = base("ramfs_2t_pf64k");
    let on = find(
        sweep(),
        "ramfs_2t_pf64k",
        RpcDispatch::Static,
        HostCoalesce::Off,
        true,
    );
    assert!(
        (on.end_ns as f64) < 0.9 * off.end_ns as f64,
        "overlap end-to-end {} vs serial {}",
        on.end_ns,
        off.end_ns
    );
    assert!(on.gbps > off.gbps);
}

#[test]
fn no_combination_regresses_the_sequential_single_stream_row() {
    let b = base("seq_4k_pf64k");
    for &(d, c, o) in &COMBOS {
        let r = find(sweep(), "seq_4k_pf64k", d, c, o);
        assert!(
            r.gbps >= 0.95 * b.gbps,
            "{}/{}/overlap={} regressed seq: {} vs {}",
            d.name(),
            c.name(),
            o,
            r.gbps,
            b.gbps
        );
    }
}

// ------------------------------------------------- direct in-sim claims

#[test]
fn overlap_moves_staging_off_the_host_critical_path() {
    let mut cfg = StackConfig::k40c_p3700();
    cfg.ramfs = true;
    cfg.gpufs.host_threads = 2;
    cfg.gpufs.prefetch_size = 64 * KIB;
    cfg.gpufs.cache_size = 256 * MIB;
    let m = Microbench::paper(4 * KIB).scaled(32);
    let off = gpufs_ra::experiments::run_micro(&cfg, &m);
    cfg.gpufs.host_overlap = true;
    let on = gpufs_ra::experiments::run_micro(&cfg, &m);
    assert_eq!(off.bytes, on.bytes);
    assert_eq!(
        off.host.iter().map(|h| h.stage_ns).sum::<u64>(),
        0,
        "serial service must not touch the staging engine"
    );
    assert!(on.host.iter().map(|h| h.stage_ns).sum::<u64>() > 0);
    // The host threads' own busy time drops by about the staging cost.
    let busy = |r: &gpufs_ra::gpufs::RunReport| r.host.iter().map(|h| h.busy_ns).sum::<u64>();
    assert!(
        busy(&on) < busy(&off),
        "busy {} vs {}",
        busy(&on),
        busy(&off)
    );
    assert!(on.end_ns < off.end_ns);
}

#[test]
fn coalescing_preserves_delivery_and_accounting() {
    // Every byte still arrives exactly once and the prefetch conservation
    // law holds with merged preads and stolen requests in play.
    let mut cfg = StackConfig::k40c_p3700();
    cfg.gpufs.cache_size = 256 * MIB;
    cfg.gpufs.rpc_dispatch = RpcDispatch::Steal;
    cfg.gpufs.host_coalesce = HostCoalesce::Adjacent;
    cfg.gpufs.host_overlap = true;
    let b = BlockCyclicBench::paper(4 * KIB).scaled(16);
    let r = gpufs_ra::experiments::run_micro_cyclic(&cfg, &b);
    assert_eq!(r.bytes, b.total_bytes());
    assert_eq!(r.rpc.requests, 120 * b.chunks_per_tb);
    // Prefetch-off workload: nothing prefetched, nothing wasted.
    assert_eq!(r.prefetch.prefetched_bytes, 0);
    // The SSD read each file byte at most once plus readahead overshoot.
    assert!(r.io.ssd_bytes <= b.total_bytes() + 8 * MIB, "ssd {}", r.io.ssd_bytes);
}

#[test]
fn steal_with_prefetch_routes_fills_correctly() {
    // Stolen requests still route their prefetch fill to the posting
    // threadblock's buffer pool (Request.stream / tb routing is intact):
    // conservation and hit counts match the static run.
    let mut cfg = StackConfig::k40c_p3700();
    cfg.gpufs.cache_size = 256 * MIB;
    cfg.gpufs.prefetch_size = 64 * KIB;
    let m = Microbench::paper(4 * KIB).scaled(16);
    let st = gpufs_ra::experiments::run_micro(&cfg, &m);
    cfg.gpufs.rpc_dispatch = RpcDispatch::Steal;
    let sl = gpufs_ra::experiments::run_micro(&cfg, &m);
    assert_eq!(st.bytes, sl.bytes);
    assert_eq!(
        sl.prefetch.useful_bytes + sl.prefetch.wasted_bytes,
        sl.prefetch.prefetched_bytes
    );
    assert!(sl.prefetch.buffer_hits > 0);
    assert!(sl.bandwidth >= 0.95 * st.bandwidth);
}
