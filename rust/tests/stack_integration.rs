//! Cross-layer integration tests: runtime ↔ artifacts ↔ pipeline, the
//! simulator under config files, fadvise/read-only gates end-to-end, and
//! failure-injection / edge-case behaviour.

use std::path::Path;

use gpufs_ra::config::{Replacement, StackConfig};
use gpufs_ra::gpufs::prefetcher::Advice;
use gpufs_ra::gpufs::{FileSpec, Gread, GpufsSim, TbProgram};
use gpufs_ra::oslayer::FileId;
use gpufs_ra::util::bytes::{GIB, KIB, MIB};

fn artifacts() -> Option<std::path::PathBuf> {
    let d = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    d.join("manifest.tsv").exists().then_some(d)
}

// ------------------------------------------------------------ runtime

#[test]
fn every_manifest_artifact_compiles_and_runs() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let rt = gpufs_ra::runtime::Runtime::load(&dir).expect("load all artifacts");
    let names: Vec<String> = rt.manifest().entries.keys().cloned().collect();
    assert!(names.len() >= 11, "expected >= 11 entries, got {names:?}");
    if names.iter().any(|n| !rt.has(n)) {
        eprintln!("skipping: no execution backend (see EXPERIMENTS.md §Runtime)");
        return;
    }
    for name in names {
        let entry = rt.manifest().get(&name).unwrap().clone();
        let inputs: Vec<Vec<f32>> = entry
            .inputs
            .iter()
            .map(|sig| (0..sig.elements()).map(|i| ((i % 11) as f32 - 5.0) / 5.0).collect())
            .collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let out = rt.execute_f32(&name, &refs).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(out.len(), entry.outputs.len(), "{name} output arity");
        for (o, sig) in out.iter().zip(&entry.outputs) {
            assert_eq!(o.len(), sig.elements(), "{name} output size");
            assert!(
                o.iter().all(|x| x.is_finite()),
                "{name} produced non-finite values"
            );
        }
    }
}

#[test]
fn stencil_artifact_preserves_borders() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let rt = gpufs_ra::runtime::Runtime::load_subset(&dir, &["stencil_tile"]).unwrap();
    if !rt.has("stencil_tile") {
        eprintln!("skipping: no execution backend (see EXPERIMENTS.md §Runtime)");
        return;
    }
    let e = rt.manifest().get("stencil_tile").unwrap();
    let (h, w) = (e.inputs[0].dims[0], e.inputs[0].dims[1]);
    let x: Vec<f32> = (0..h * w).map(|i| (i % 13) as f32).collect();
    let out = &rt.execute_f32("stencil_tile", &[&x]).unwrap()[0];
    for j in 0..w {
        assert_eq!(out[j], x[j], "top border changed");
        assert_eq!(out[(h - 1) * w + j], x[(h - 1) * w + j], "bottom border");
    }
}

// ------------------------------------------------------- sim + config

#[test]
fn config_file_drives_the_simulator() {
    let dir = std::env::temp_dir();
    let path = dir.join("gpufs_ra_cfg_test.toml");
    std::fs::write(
        &path,
        "[gpufs]\npage_size = 64K\ncache_size = 64M\nprefetch_size = 0\n[seedless]\n",
    )
    .unwrap();
    let mut cfg = StackConfig::k40c_p3700();
    // the bogus [seedless] section has no keys, so it must be harmless
    cfg.load_file(path.to_str().unwrap()).unwrap();
    assert_eq!(cfg.gpufs.page_size, 64 * KIB);
    assert_eq!(cfg.gpufs.cache_size, 64 * MIB);
    let _ = std::fs::remove_file(path);
}

#[test]
fn mixed_advice_files_prefetch_selectively() {
    // One sequential read-only file (prefetch on) + one random-advised
    // file (prefetch off) in the same run — the paper's collage scenario.
    let mut cfg = StackConfig::k40c_p3700();
    cfg.gpufs.cache_size = 64 * MIB;
    cfg.gpufs.prefetch_size = 64 * KIB;
    let files = vec![
        FileSpec::read_only(64 * MIB),
        FileSpec {
            size: 64 * MIB,
            read_only: true,
            advice: Advice::Random,
        },
    ];
    let programs: Vec<TbProgram> = (0..8u32)
        .map(|tb| {
            let base = tb as u64 * MIB;
            let mut reads = Vec::new();
            for i in 0..64 {
                reads.push(Gread {
                    file: FileId(0),
                    offset: base + i * 4 * KIB,
                    len: 4 * KIB,
                });
                reads.push(Gread {
                    file: FileId(1),
                    offset: ((i * 7919 + tb as u64 * 104729) % (16 * KIB)) * 4 * KIB,
                    len: 4 * KIB,
                });
            }
            TbProgram {
                reads,
                compute_ns_per_read: 0,
                rmw: false,
            }
        })
        .collect();
    let r = GpufsSim::new(&cfg, files, programs, 512).run();
    // Prefetch requests happened (file 0) but none were wasted on file 1's
    // random accesses beyond buffer replacement effects.
    assert!(r.prefetch.inflated_requests > 0);
    assert!(r.prefetch.buffer_hits > 0);
    assert_eq!(r.bytes, 2 * 8 * 64 * 4 * KIB);
}

#[test]
fn one_threadblock_degenerate_launch() {
    let mut cfg = StackConfig::k40c_p3700();
    cfg.gpufs.cache_size = 16 * MIB;
    let files = vec![FileSpec::read_only(GIB)];
    let programs = vec![TbProgram {
        reads: (0..64)
            .map(|i| Gread {
                file: FileId(0),
                offset: i * 64 * KIB,
                len: 64 * KIB,
            })
            .collect(),
        compute_ns_per_read: 1000,
        rmw: false,
    }];
    let r = GpufsSim::new(&cfg, files, programs, 512).run();
    assert_eq!(r.bytes, 4 * MIB);
    assert!(r.bandwidth > 0.0);
}

#[test]
fn empty_program_threadblocks_retire_cleanly() {
    let cfg = StackConfig::k40c_p3700();
    let files = vec![FileSpec::read_only(MIB)];
    let programs = vec![TbProgram::default(); 4];
    let r = GpufsSim::new(&cfg, files, programs, 512).run();
    assert_eq!(r.bytes, 0);
    assert_eq!(r.rpc.requests, 0);
}

#[test]
fn unaligned_gread_offsets_are_served() {
    let mut cfg = StackConfig::k40c_p3700();
    cfg.gpufs.cache_size = 16 * MIB;
    let files = vec![FileSpec::read_only(GIB)];
    // greads that straddle page boundaries.
    let programs = vec![TbProgram {
        reads: vec![
            Gread { file: FileId(0), offset: 1000, len: 10_000 },
            Gread { file: FileId(0), offset: 1_000_000, len: 3 * KIB },
        ],
        compute_ns_per_read: 0,
        rmw: false,
    }];
    let r = GpufsSim::new(&cfg, files, programs, 512).run();
    assert_eq!(r.bytes, 13_000 + 72);
    assert!(r.rpc.requests >= 2);
}

#[test]
fn per_tb_lra_handles_many_waves() {
    // 120 tbs, 60 resident, cache sized so waves must inherit orphans.
    let mut cfg = StackConfig::k40c_p3700();
    cfg.gpufs.cache_size = 8 * MIB;
    cfg.gpufs.prefetch_size = 64 * KIB;
    cfg.gpufs.replacement = Replacement::PerTbLra;
    let files = vec![FileSpec::read_only(GIB)];
    let programs: Vec<TbProgram> = (0..120u32)
        .map(|tb| TbProgram {
            reads: (0..64)
                .map(|i| Gread {
                    file: FileId(0),
                    offset: tb as u64 * 4 * MIB + i * 4 * KIB,
                    len: 4 * KIB,
                })
                .collect(),
            compute_ns_per_read: 0,
            rmw: false,
        })
        .collect();
    let r = GpufsSim::new(&cfg, files, programs, 512).run();
    assert_eq!(r.bytes, 120 * 64 * 4 * KIB);
    assert_eq!(r.cache.global_evictions, 0);
}

// -------------------------------------------------- sim ablation knobs

#[test]
fn ablation_fewer_host_threads_worsen_the_slot_imbalance() {
    // The Fig 6 pathology scales with the slot partition: with 2 host
    // threads (64 slots each) the entire first occupancy wave (slots
    // 0..59) lands on thread 0 ALONE, halving service parallelism in the
    // thread-bound small-request regime.
    let mut cfg = StackConfig::k40c_p3700();
    cfg.gpufs.page_size = 4 * KIB;
    cfg.gpufs.cache_size = GIB;
    cfg.no_pcie = true;
    let m = gpufs_ra::workload::Microbench::paper(4 * KIB).scaled(8);
    let four = gpufs_ra::experiments::run_micro(&cfg, &m);
    cfg.gpufs.host_threads = 2;
    let two = gpufs_ra::experiments::run_micro(&cfg, &m);
    assert!(
        four.bandwidth > 1.3 * two.bandwidth,
        "4 threads {} vs 2 threads {}",
        four.bandwidth,
        two.bandwidth
    );
}

#[test]
fn ablation_disabling_linux_readahead_tanks_everything() {
    let mut cfg = StackConfig::k40c_p3700();
    cfg.gpufs.cache_size = 256 * MIB;
    cfg.gpufs.prefetch_size = 64 * KIB;
    let m = gpufs_ra::workload::Microbench::paper(4 * KIB).scaled(8);
    let with_ra = gpufs_ra::experiments::run_micro(&cfg, &m);
    cfg.readahead.enabled = false;
    let without = gpufs_ra::experiments::run_micro(&cfg, &m);
    assert!(
        with_ra.bandwidth > 2.0 * without.bandwidth,
        "RA on {} vs off {}",
        with_ra.bandwidth,
        without.bandwidth
    );
}

// --------------------------- §4.1.1 future work: dirty-bitmap coherency

#[test]
fn dirty_bitmap_enables_prefetch_on_writable_files() {
    use gpufs_ra::config::Coherency;
    let mut cfg = StackConfig::k40c_p3700();
    cfg.gpufs.cache_size = 64 * MIB;
    cfg.gpufs.prefetch_size = 64 * KIB;
    let files = vec![FileSpec {
        size: 256 * MIB,
        read_only: false,
        advice: Advice::Normal,
    }];
    let programs: Vec<TbProgram> = (0..8u32)
        .map(|tb| TbProgram {
            reads: (0..256)
                .map(|i| Gread {
                    file: FileId(0),
                    offset: tb as u64 * 4 * MIB + i * 4 * KIB,
                    len: 4 * KIB,
                })
                .collect(),
            compute_ns_per_read: 0,
            rmw: false,
        })
        .collect();
    // Shipped design: writable => no prefetch.
    let gate = GpufsSim::new(&cfg, files.clone(), programs.clone(), 512).run();
    assert_eq!(gate.prefetch.inflated_requests, 0);
    // Future-work design: dirty bitmap makes it safe.
    cfg.gpufs.coherency = Coherency::DirtyBitmap;
    let bitmap = GpufsSim::new(&cfg, files, programs, 512).run();
    assert!(bitmap.prefetch.inflated_requests > 0);
    assert!(bitmap.prefetch.buffer_hits > 0);
    assert!(
        bitmap.bandwidth > 1.5 * gate.bandwidth,
        "prefetching writable files must pay off: {} vs {}",
        bitmap.bandwidth,
        gate.bandwidth
    );
}

#[test]
fn writes_invalidate_other_threadblocks_private_buffers() {
    use gpufs_ra::config::Coherency;
    let mut cfg = StackConfig::k40c_p3700();
    cfg.gpufs.cache_size = 64 * MIB;
    cfg.gpufs.prefetch_size = 64 * KIB;
    cfg.gpufs.coherency = Coherency::DirtyBitmap;
    let files = vec![FileSpec {
        size: 64 * MIB,
        read_only: false,
        advice: Advice::Normal,
    }];
    // The paper's §4.1.1 hazard, verbatim: a page is retrieved by
    // multiple threadblocks (copies in private buffers), modified in the
    // page cache by one of them, and THEN EVICTED from the page cache —
    // the remaining private-buffer copy is stale.
    //
    // TB0 reads pages 0..17 slowly (5 ms compute per read): its private
    // buffer fills at the page-0 miss, covering pages 1..17.  TB1
    // read-modify-writes the same pages quickly (dirtying them), then
    // streams a far region so the tiny cache evicts pages 1..17.  When
    // TB0 resumes, its page-cache probes miss and the private-buffer
    // copies must be discarded as stale.
    cfg.gpufs.cache_size = 256 * 4 * KIB; // 256 frames -> fast eviction
    let slow_reader = TbProgram {
        reads: (0..17)
            .map(|i| Gread {
                file: FileId(0),
                offset: i * 4 * KIB,
                len: 4 * KIB,
            })
            .collect(),
        compute_ns_per_read: 5_000_000,
        rmw: false,
    };
    let mut writer_reads: Vec<Gread> = (1..17)
        .map(|i| Gread {
            file: FileId(0),
            offset: i * 4 * KIB,
            len: 4 * KIB,
        })
        .collect();
    // Evict the dirtied pages by streaming 512 far pages through the
    // 256-frame cache.
    writer_reads.extend((0..512).map(|i| Gread {
        file: FileId(0),
        offset: 16 * MIB + i * 4 * KIB,
        len: 4 * KIB,
    }));
    let fast_writer = TbProgram {
        reads: writer_reads,
        compute_ns_per_read: 0,
        rmw: true,
    };
    let r = GpufsSim::new(&cfg, files, vec![slow_reader, fast_writer], 512).run();
    assert!(
        r.rpc.stale_discards > 0,
        "TB0 must discard dirtied private-buffer pages (got {} discards)",
        r.rpc.stale_discards
    );
}

#[test]
fn read_only_workload_identical_under_both_coherency_modes() {
    use gpufs_ra::config::Coherency;
    let mut cfg = StackConfig::k40c_p3700();
    cfg.gpufs.cache_size = 128 * MIB;
    cfg.gpufs.prefetch_size = 64 * KIB;
    let m = gpufs_ra::workload::Microbench::paper(4 * KIB).scaled(16);
    let gate = gpufs_ra::experiments::run_micro(&cfg, &m);
    cfg.gpufs.coherency = Coherency::DirtyBitmap;
    let bitmap = gpufs_ra::experiments::run_micro(&cfg, &m);
    assert_eq!(gate.end_ns, bitmap.end_ns, "no writes => no difference");
    assert_eq!(bitmap.rpc.stale_discards, 0);
}
