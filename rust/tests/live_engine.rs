//! Sim/live parity and live-engine integration tests.
//!
//! The live engine runs the *same policy code* as the simulator (stream
//! table + ramp policy, buffer pool, page cache, RPC dispatch), so with
//! timing excluded the two engines must make identical decisions over
//! the same workload: identical per-threadblock request streams
//! (offset, demand, prefetch grant), identical host pread counts and
//! served bytes, identical prefetch accounting.  That holds whenever
//! cross-threadblock timing cannot leak into policy state — disjoint
//! strides, no cache evictions, `host_coalesce = off` (coalescing merges
//! whatever lands in one poll batch, which IS timing) — which is exactly
//! the default-config regime the parity tests pin.
//!
//! The live-only tests check what the simulator cannot: that the real
//! bytes land at the right offsets (positional checksum vs. an oracle
//! pass), through every delivery path — RPC replies, private-buffer
//! hits, page-cache hits, and page-cache evictions.

use std::path::PathBuf;

use gpufs_ra::config::{PrefetchMode, StackConfig};
use gpufs_ra::engine::EngineKind;
use gpufs_ra::gpufs::live::{self, LiveFile};
use gpufs_ra::gpufs::{FileSpec, GpufsSim, Gread, RunReport, TbProgram};
use gpufs_ra::oslayer::FileId;
use gpufs_ra::util::bytes::{KIB, MIB};
use gpufs_ra::workload::Microbench;

/// The shared parity workload: 4 threadblocks × 256 KiB disjoint strides
/// of a 1 MiB file, 4 KiB greads (sequential row of the microbenchmark).
fn parity_micro() -> Microbench {
    Microbench {
        n_tbs: 4,
        stride: 256 * KIB,
        io: 4 * KIB,
        file_size: MIB,
        compute_ns_per_read: 0,
    }
}

fn live_file(m: &Microbench, tag: &str) -> PathBuf {
    let path = std::env::temp_dir().join(format!("gpufs_ra_parity_{tag}.bin"));
    gpufs_ra::experiments::live::ensure_test_file(&path, m.file_size).unwrap();
    path
}

/// Run the same workload through both engines; the sim records its grant
/// log, the live engine records its own.
fn run_pair(cfg: &StackConfig, m: &Microbench, tag: &str) -> (RunReport, live::LiveRun) {
    let sim = GpufsSim::new(cfg, m.files(), m.programs(), 512)
        .with_grant_log()
        .run();
    let path = live_file(m, tag);
    let files: Vec<LiveFile> = m
        .files()
        .into_iter()
        .map(|spec| LiveFile {
            path: path.clone(),
            spec,
        })
        .collect();
    let mut live_cfg = cfg.clone();
    live_cfg.engine = EngineKind::Live;
    let run = live::run(&live_cfg, &files, m.programs(), 512, true).unwrap();
    (sim, run)
}

fn assert_parity(name: &str, sim: &RunReport, live: &live::LiveRun) {
    let lr = &live.report;
    assert_eq!(sim.grants, lr.grants, "{name}: request/grant streams diverged");
    assert_eq!(sim.io.preads, lr.io.preads, "{name}: host pread counts diverged");
    assert_eq!(sim.rpc.requests, lr.rpc.requests, "{name}: rpc counts diverged");
    assert_eq!(sim.bytes, lr.bytes, "{name}: delivered bytes diverged");
    let served = |r: &RunReport| r.host.iter().map(|h| h.bytes).sum::<u64>();
    assert_eq!(served(sim), served(lr), "{name}: served host bytes diverged");
    let p = (&sim.prefetch, &lr.prefetch);
    assert_eq!(p.0.prefetched_bytes, p.1.prefetched_bytes, "{name}: prefetched");
    assert_eq!(p.0.buffer_hits, p.1.buffer_hits, "{name}: buffer hits");
    assert_eq!(p.0.useful_bytes, p.1.useful_bytes, "{name}: useful bytes");
    assert_eq!(p.0.wasted_bytes, p.1.wasted_bytes, "{name}: wasted bytes");
    assert_eq!(p.0.inflated_requests, p.1.inflated_requests, "{name}: inflated");
    // GPU page-cache accounting lines up too (the live engine counts
    // probes exactly where the sim does).
    assert_eq!(sim.cache.lookups, lr.cache.lookups, "{name}: cache lookups");
    assert_eq!(sim.cache.hits, lr.cache.hits, "{name}: cache hits");
    assert_eq!(sim.cache.allocs, lr.cache.allocs, "{name}: cache allocs");
}

#[test]
fn parity_prefetch_off_default_config() {
    // The acceptance anchor: default config (prefetch off, static
    // dispatch, no coalescing), identical pread counts / bytes / request
    // sequences.  Every 4 KiB gread is one demand-only request, pread one
    // GPUfs page at a time.
    let cfg = StackConfig::k40c_p3700();
    let m = parity_micro();
    let (sim, live) = run_pair(&cfg, &m, "off");
    assert_parity("prefetch_off", &sim, &live);
    assert_eq!(sim.rpc.requests, 4 * 64, "one request per 4K gread");
    assert_eq!(sim.io.preads, 4 * 64, "one pread per demand page");
    assert_eq!(sim.prefetch.prefetched_bytes, 0);
}

#[test]
fn parity_fixed_64k_prefetch() {
    // PREFETCH_SIZE = 64 KiB: one inflated request per 68 KiB of stream,
    // 16 of every 17 greads served from the private buffer — in both
    // engines, with the identical grant sequence.
    let mut cfg = StackConfig::k40c_p3700();
    cfg.gpufs.prefetch_size = 64 * KIB;
    let m = parity_micro();
    let (sim, live) = run_pair(&cfg, &m, "64k");
    assert_parity("fixed_64k", &sim, &live);
    assert!(sim.prefetch.buffer_hits > 0);
    assert!(sim.rpc.requests < 4 * 64 / 10, "prefetcher must cut RPCs ~17x");
}

#[test]
fn parity_adaptive_windows() {
    // The adaptive engine's per-stream ramp depends only on the
    // threadblock's own miss sequence, so its grant stream is
    // timing-independent too.
    let mut cfg = StackConfig::k40c_p3700();
    cfg.gpufs.prefetch_mode = PrefetchMode::Adaptive;
    let m = parity_micro();
    let (sim, live) = run_pair(&cfg, &m, "adaptive");
    assert_parity("adaptive", &sim, &live);
    assert!(sim.prefetch.inflated_requests > 0, "adaptive must open windows");
    // Windows actually ramp: later grants exceed the first non-zero one.
    let g0 = &sim.grants[0];
    let first = g0.iter().find(|g| g.prefetch > 0).unwrap().prefetch;
    let max = g0.iter().map(|g| g.prefetch).max().unwrap();
    assert!(max > first, "ramp never grew: first {first}, max {max}");
}

#[test]
fn live_checksum_verifies_against_oracle() {
    // Every delivered byte is real and lands at the right offset; the
    // prefetch path (buffer hits) is exercised.
    let mut cfg = StackConfig::k40c_p3700();
    cfg.engine = EngineKind::Live;
    cfg.gpufs.prefetch_size = 64 * KIB;
    let m = parity_micro();
    let path = live_file(&m, "checksum");
    let files = vec![LiveFile {
        path,
        spec: FileSpec::read_only(m.file_size),
    }];
    let programs = m.programs();
    let expect = live::expected_checksum(&files, &programs).unwrap();
    let run = live::run(&cfg, &files, programs, 512, false).unwrap();
    assert_eq!(run.checksum, expect, "live bytes diverged from the file");
    assert!(run.report.prefetch.buffer_hits > 0);
    assert!(run.report.end_ns > 0);
    assert!(run.report.bandwidth > 0.0);
}

#[test]
fn live_steal_and_coalesce_serve_correct_bytes() {
    // The non-default host knobs change *which thread serves what and in
    // how many preads* (timing-dependent, so no count parity) — but never
    // the bytes.  host_overlap is accepted (and inert) on live.
    let mut cfg = StackConfig::k40c_p3700();
    cfg.engine = EngineKind::Live;
    cfg.gpufs.prefetch_size = 64 * KIB;
    cfg.gpufs.rpc_dispatch = gpufs_ra::config::RpcDispatch::Steal;
    cfg.gpufs.host_coalesce = gpufs_ra::config::HostCoalesce::Adjacent;
    cfg.gpufs.host_overlap = true;
    let m = parity_micro();
    let path = live_file(&m, "knobs");
    let files = vec![LiveFile {
        path,
        spec: FileSpec::read_only(m.file_size),
    }];
    let programs = m.programs();
    let expect = live::expected_checksum(&files, &programs).unwrap();
    let run = live::run(&cfg, &files, programs, 512, false).unwrap();
    assert_eq!(run.checksum, expect);
    // Merge accounting stays consistent whether or not batches merged:
    // every coalesced pread absorbs at least one extra request.
    let merged: u64 = run.report.host.iter().map(|h| h.merged).sum();
    assert!(
        merged >= run.report.io.merged_preads,
        "host merged counter {merged} < merged preads {}",
        run.report.io.merged_preads
    );
}

#[test]
fn live_rereads_and_evictions_preserve_bytes() {
    // Two passes over the same range: with a cache smaller than the
    // working set, the second pass mixes page-cache hits with refetches
    // of evicted pages.  The checksum proves evicted frames are really
    // dropped and refetched with correct data (the live shard's
    // eviction path).
    let mut cfg = StackConfig::k40c_p3700();
    cfg.engine = EngineKind::Live;
    cfg.gpufs.cache_size = 32 * 4 * KIB; // 32 pages < 64-page working set
    let path = std::env::temp_dir().join("gpufs_ra_parity_evict.bin");
    gpufs_ra::experiments::live::ensure_test_file(&path, 256 * KIB).unwrap();
    let files = vec![LiveFile {
        path,
        spec: FileSpec::read_only(256 * KIB),
    }];
    // Forward pass fills (and thrashes) the cache; the reverse pass then
    // hits the resident tail before refetching the evicted head.  (A
    // forward-forward repeat would FIFO-thrash to zero hits.)
    let gread = |i: u64| Gread {
        file: FileId(0),
        offset: i * 4 * KIB,
        len: 4 * KIB,
    };
    let mut reads: Vec<Gread> = (0..64u64).map(gread).collect();
    reads.extend((0..64u64).rev().map(gread));
    let programs = vec![TbProgram {
        reads,
        compute_ns_per_read: 0,
        rmw: false,
    }];
    let expect = live::expected_checksum(&files, &programs).unwrap();
    let run = live::run(&cfg, &files, programs, 512, false).unwrap();
    assert_eq!(run.checksum, expect, "evicted pages must refetch correctly");
    assert!(run.report.cache.global_evictions > 0, "working set must thrash");
    assert!(run.report.cache.hits > 0, "some pages must survive to the re-read");
}

#[test]
fn live_sharded_cache_and_atomic_claims_preserve_bytes() {
    // The contention-proofed hot path under real concurrency: 8 host
    // threads, 8 cache shards, steal dispatch, and a cache small enough
    // (32 pages, 8-page shards) that the two-pass workload evicts and
    // refetches across every shard.  The oracle checksum proves no byte
    // was lost, duplicated, or misplaced by the CAS claim path or the
    // per-shard locks; the folded stats stay conservation-consistent
    // with the request stream.
    let mut cfg = StackConfig::k40c_p3700();
    cfg.engine = EngineKind::Live;
    cfg.gpufs.cache_size = 32 * 4 * KIB;
    cfg.gpufs.cache_shards = 8;
    cfg.gpufs.host_threads = 8;
    cfg.gpufs.rpc_dispatch = gpufs_ra::config::RpcDispatch::Steal;
    cfg.gpufs.prefetch_size = 64 * KIB;
    let path = std::env::temp_dir().join("gpufs_ra_parity_shard.bin");
    gpufs_ra::experiments::live::ensure_test_file(&path, 512 * KIB).unwrap();
    let files = vec![LiveFile {
        path,
        spec: FileSpec::read_only(512 * KIB),
    }];
    let gread = |i: u64| Gread {
        file: FileId(0),
        offset: i * 4 * KIB,
        len: 4 * KIB,
    };
    // 4 threadblocks × disjoint 32-page strides, forward then reverse —
    // the reverse pass mixes shard-local hits with refetches of evicted
    // frames, on every shard at once.
    let programs: Vec<TbProgram> = (0..4u64)
        .map(|tb| {
            let lo = tb * 32;
            let mut reads: Vec<Gread> = (lo..lo + 32).map(gread).collect();
            reads.extend((lo..lo + 32).rev().map(gread));
            TbProgram {
                reads,
                compute_ns_per_read: 0,
                rmw: false,
            }
        })
        .collect();
    let expect = live::expected_checksum(&files, &programs).unwrap();
    let run = live::run(&cfg, &files, programs, 512, false).unwrap();
    let r = &run.report;
    assert_eq!(run.checksum, expect, "sharded live bytes diverged from the file");
    assert_eq!(r.host.len(), 8, "one stats accumulator per host thread");
    let served: u64 = r.host.iter().map(|h| h.served).sum();
    assert_eq!(served, r.rpc.requests, "per-thread served must fold to the rpc total");
    assert!(r.cache.global_evictions > 0, "working set must thrash the shards");
    assert!(r.cache.hits > 0, "some pages must survive to the re-read");
    assert!(
        r.cache.lookups >= r.cache.hits,
        "folded shard counters lost conservation"
    );
}

#[test]
fn live_zerocopy_cuts_staging_copies_and_preserves_bytes() {
    // The same workload under both staging modes.  Both must fold the
    // oracle checksum; zero-copy must cut `bytes_copied` to at most
    // half of the copy path (PR 7 acceptance) — demand pages land
    // directly in page-cache frames and prefetch tails arrive as
    // per-page pool frames, so neither pays the bounce-buffer copy.
    let mut base = StackConfig::k40c_p3700();
    base.engine = EngineKind::Live;
    base.gpufs.prefetch_size = 64 * KIB;
    let m = parity_micro();
    let path = live_file(&m, "staging");
    let files = vec![LiveFile {
        path,
        spec: FileSpec::read_only(m.file_size),
    }];
    let programs = m.programs();
    let expect = live::expected_checksum(&files, &programs).unwrap();

    let copy = live::run(&base, &files, programs.clone(), 512, false).unwrap();
    assert_eq!(copy.checksum, expect, "copy-staging bytes diverged from the file");
    assert!(
        copy.report.xfer.bytes_copied > 0,
        "copy staging must stage through bounce buffers"
    );

    let mut zc = base.clone();
    zc.set("host.staging", "zerocopy").unwrap();
    let z = live::run(&zc, &files, programs, 512, false).unwrap();
    assert_eq!(z.checksum, expect, "zero-copy bytes diverged from the file");
    assert!(z.report.prefetch.buffer_hits > 0, "prefetch path must be exercised");
    assert!(
        2 * z.report.xfer.bytes_copied <= copy.report.xfer.bytes_copied,
        "zerocopy copied {} bytes vs copy staging's {} — not even a 2x cut",
        z.report.xfer.bytes_copied,
        copy.report.xfer.bytes_copied
    );
}

#[test]
fn live_zerocopy_eviction_refetch_checksum_oracle() {
    // Zero-copy staging with a thrashing cache and a deep submission
    // window: reserved frames are in-flight read destinations while
    // eviction churns around them (a reserved slot must never be a
    // victim, or its bytes would land in a recycled frame), and every
    // evicted page must refetch through reserve→publish with correct
    // data.  The positional checksum catches any of those going wrong.
    let mut cfg = StackConfig::k40c_p3700();
    cfg.engine = EngineKind::Live;
    cfg.set("host.staging", "zerocopy").unwrap();
    cfg.set("host.io_depth", "4").unwrap();
    cfg.gpufs.cache_size = 32 * 4 * KIB; // 32 pages < 64-page working set
    let path = std::env::temp_dir().join("gpufs_ra_parity_zc_evict.bin");
    gpufs_ra::experiments::live::ensure_test_file(&path, 256 * KIB).unwrap();
    let files = vec![LiveFile {
        path,
        spec: FileSpec::read_only(256 * KIB),
    }];
    let gread = |i: u64| Gread {
        file: FileId(0),
        offset: i * 4 * KIB,
        len: 4 * KIB,
    };
    let mut reads: Vec<Gread> = (0..64u64).map(gread).collect();
    reads.extend((0..64u64).rev().map(gread));
    let programs = vec![TbProgram {
        reads,
        compute_ns_per_read: 0,
        rmw: false,
    }];
    let expect = live::expected_checksum(&files, &programs).unwrap();
    let run = live::run(&cfg, &files, programs, 512, false).unwrap();
    assert_eq!(run.checksum, expect, "zero-copy refetched pages diverged");
    assert!(run.report.cache.global_evictions > 0, "working set must thrash");
    assert!(run.report.cache.hits > 0, "some pages must survive to the re-read");
    assert_eq!(
        run.report.xfer.bytes_copied, 0,
        "demand-only zero-copy must not stage a single byte"
    );
}

#[test]
fn live_io_depth_8_copy_staging_preserves_bytes() {
    // Deep submission window with the default copy staging: each host
    // keeps up to 8 group reads in flight through its reader pool and
    // reaps completions out of order, but every reply must still carry
    // its own request's bytes to its own threadblock.
    let mut cfg = StackConfig::k40c_p3700();
    cfg.engine = EngineKind::Live;
    cfg.set("host.io_depth", "8").unwrap();
    cfg.gpufs.prefetch_size = 64 * KIB;
    let m = parity_micro();
    let path = live_file(&m, "qd8");
    let files = vec![LiveFile {
        path,
        spec: FileSpec::read_only(m.file_size),
    }];
    let programs = m.programs();
    let expect = live::expected_checksum(&files, &programs).unwrap();
    let run = live::run(&cfg, &files, programs, 512, false).unwrap();
    assert_eq!(run.checksum, expect, "out-of-order completions misdelivered bytes");
    assert_eq!(run.report.bytes, 4 * 256 * KIB);
    assert!(run.report.prefetch.buffer_hits > 0);
}

#[test]
fn live_micro_harness_runs_and_verifies() {
    // The `micro --engine live` path end to end, tiny: file sized to the
    // accessed region, oracle-verified checksum.
    let mut cfg = StackConfig::k40c_p3700();
    cfg.engine = EngineKind::Live;
    cfg.gpufs.prefetch_size = 64 * KIB;
    let m = Microbench {
        n_tbs: 8,
        stride: 128 * KIB,
        io: 4 * KIB,
        file_size: 10 << 30, // run_micro_live shrinks this to the region
        compute_ns_per_read: 0,
    };
    let tmp = std::env::temp_dir();
    let (run, ok) = gpufs_ra::experiments::live::run_micro_live(&cfg, &m, Some(&tmp)).unwrap();
    assert!(ok, "checksum mismatch");
    assert_eq!(run.report.bytes, 8 * 128 * KIB);
    assert!(run.report.prefetch.buffer_hits > 0);
}
