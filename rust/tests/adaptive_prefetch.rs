//! The adaptive readahead engine, end to end, plus the proof that the
//! OS-layer refactor onto the shared core is a true extraction.
//!
//! Two halves:
//!
//! 1. **Decision-trace equivalence** — a verbatim copy of the
//!    pre-refactor `ondemand_readahead` (with its inline window formulas)
//!    is replayed against the refactored implementation over thousands of
//!    recorded access situations; every `RaDecision` must match exactly.
//! 2. **Adaptive vs fixed, in the full simulator** — the acceptance
//!    claims of the adaptive engine: ≥ the best fixed PREFETCH_SIZE on
//!    the sequential microbenchmark, no regression vs prefetch-off on
//!    random access, and sane behaviour on strided / interleaved streams.

use gpufs_ra::config::{PrefetchMode, StackConfig};
use gpufs_ra::experiments::fig_adaptive;
use gpufs_ra::oslayer::page_cache::CachedFile;
use gpufs_ra::oslayer::readahead::{ondemand_readahead, RaDecision, RaState};
use gpufs_ra::oslayer::PageState;
use gpufs_ra::util::bytes::KIB;
use gpufs_ra::util::prng::Prng;

// ------------------------------------------------- trace equivalence

/// The pre-refactor implementation, copied verbatim from the seed's
/// `oslayer/readahead.rs` (inline `get_init_ra_size` / `get_next_ra_size`
/// formulas instead of the shared-core policy).
mod legacy {
    use gpufs_ra::oslayer::page_cache::CachedFile;
    use gpufs_ra::oslayer::readahead::RaDecision;

    fn init_ra_size(req: u64, max: u64) -> u64 {
        let mut newsize = req.next_power_of_two();
        if newsize <= max / 32 {
            newsize *= 4;
        } else if newsize <= max / 4 {
            newsize *= 2;
        } else {
            newsize = max;
        }
        newsize
    }

    fn next_ra_size(cur: u64, max: u64) -> u64 {
        if cur < max / 16 {
            (cur * 4).min(max)
        } else {
            (cur * 2).min(max)
        }
    }

    pub fn ondemand_readahead(
        file: &CachedFile,
        max: u64,
        offset: u64,
        req: u64,
        hit_marker: bool,
    ) -> Option<RaDecision> {
        let ra = &file.ra;
        let req = req.max(1);

        if ra.size > 0 && offset == ra.start + ra.size - ra.async_size && offset != 0 {
            let start = ra.start + ra.size;
            let size = next_ra_size(ra.size, max);
            return Some(decide(start, size, size));
        }

        if hit_marker {
            let start = file.first_absent_from(offset + 1)?;
            let hist = file.history_run(offset + 1, max);
            let size = next_ra_size(hist.max(req).max(1), max).min(max);
            return Some(decide(start, size, size));
        }

        if offset == 0 || offset as i64 == ra.prev_page + 1 {
            let size = init_ra_size(req, max).max(req.min(max)).min(max.max(req));
            let size = size.min(max.max(1));
            let async_size = size.saturating_sub(req);
            return Some(decide(offset, size, async_size));
        }

        let hist = file.history_run(offset, max);
        if hist > 0 {
            let size = next_ra_size(hist.max(req), max).min(max);
            let async_size = size.saturating_sub(req);
            return Some(decide(offset, size, async_size));
        }

        None
    }

    fn decide(start: u64, size: u64, async_size: u64) -> RaDecision {
        let marker = if async_size > 0 && async_size <= size {
            Some(start + size - async_size)
        } else {
            None
        };
        RaDecision {
            start,
            size,
            marker,
        }
    }
}

/// Replay one access situation against both implementations.
fn check_equal(file: &CachedFile, max: u64, offset: u64, req: u64, hit_marker: bool) {
    let new = ondemand_readahead(file, max, offset, req, hit_marker);
    let old = legacy::ondemand_readahead(file, max, offset, req, hit_marker);
    assert_eq!(
        new, old,
        "decision diverged at max={max} offset={offset} req={req} marker={hit_marker} ra={:?}",
        file.ra
    );
}

#[test]
fn decision_trace_equivalence_scripted_patterns() {
    // Sequential, oversize, strided, and random request traces over an
    // evolving cache, exercising branches A/C/D/E of the decision.
    for max in [8u64, 16, 32, 64] {
        let mut f = CachedFile::new(4096 * 4096);
        let mut offset = 0u64;
        // Fresh-stream ramp: sequential 1-page requests.
        for _ in 0..50 {
            check_equal(&f, max, offset, 1, false);
            if let Some(d) = ondemand_readahead(&f, max, offset, 1, false) {
                for p in d.start..(d.start + d.size).min(f.n_pages()) {
                    if f.slot(p).state() == PageState::Absent {
                        f.set_in_flight(p, 0);
                        f.mark_present(p);
                    }
                }
                f.ra.start = d.start;
                f.ra.size = d.size;
                f.ra.async_size = d.marker.map(|m| d.start + d.size - m).unwrap_or(0);
            }
            f.ra.prev_page = offset as i64;
            offset += 1;
        }
        // Marker hits at and off the shared-window position (branches A/B).
        for probe in [
            f.ra.start + f.ra.size - f.ra.async_size.min(f.ra.size),
            offset + 100,
            offset + 7,
        ] {
            check_equal(&f, max, probe, 1, true);
        }
        // Oversize requests (the 128K cliff) and strided sync misses.
        for req in [max / 2, max, 2 * max, 4 * max] {
            check_equal(&f, max, offset, req.max(1), false);
        }
        for stride in [2u64, 8, 64] {
            let mut o = 2000;
            for _ in 0..20 {
                check_equal(&f, max, o, 1, false);
                o += stride;
            }
        }
    }
}

#[test]
fn decision_trace_equivalence_randomized() {
    // 5000 randomized situations per max: arbitrary fd state, partially
    // populated cache, random (offset, req, marker) probes.
    for max in [8u64, 32, 128] {
        let mut rng = Prng::new(0xD15C * max);
        let pages = 8192u64;
        let mut f = CachedFile::new(pages * 4096);
        // Populate scattered runs so history_run/first_absent_from see
        // every shape.
        let mut p = 0u64;
        while p < pages {
            let run = rng.gen_range(6);
            for q in p..(p + run).min(pages) {
                f.set_in_flight(q, 0);
                f.mark_present(q);
            }
            p += run + 1 + rng.gen_range(10);
        }
        for _ in 0..5000 {
            // async_size never exceeds size (true of every committed
            // window; larger values would underflow the marker position
            // in both implementations alike).
            let size = rng.gen_range(max + 1);
            let async_size = rng.gen_range(size + 1).min(size);
            f.ra = RaState {
                start: rng.gen_range(pages),
                size,
                async_size,
                prev_page: rng.gen_range(pages) as i64 - 1,
            };
            let offset = rng.gen_range(pages);
            let req = 1 + rng.gen_range(2 * max);
            let marker = rng.gen_range(2) == 0;
            check_equal(&f, max, offset, req, marker);
        }
    }
}

// ------------------------------------------- adaptive engine, in-sim

fn cfg() -> StackConfig {
    StackConfig::k40c_p3700()
}

/// One shared fig_adaptive sweep (4 workloads × {off, fixed grid,
/// adaptive × slots grid}) for every in-sim assertion below — the sweep
/// is by far the most expensive part of this suite.
fn rows() -> &'static [fig_adaptive::AdaptiveRow] {
    use std::sync::OnceLock;
    static ROWS: OnceLock<Vec<fig_adaptive::AdaptiveRow>> = OnceLock::new();
    ROWS.get_or_init(|| fig_adaptive::run(&cfg(), 8).0)
}

fn row(name: &str) -> &'static fig_adaptive::AdaptiveRow {
    rows().iter().find(|r| r.workload == name).unwrap()
}

// Band provenance (re-derived for PR 2, still without a local
// toolchain — the bands below follow from the model's mechanics rather
// than from tuned measurements):
// * random: the adaptive engine issues zero grants on the Mosaic
//   pattern (far jumps never confirm a stream), so the run is
//   event-identical to prefetch-off — the 0.98 band only absorbs
//   float noise in the bandwidth division.
// * strided (32 KiB step = 8 pages per 1-page demand): the stride locks
//   as sparse and is granted nothing, so again event-identical to off.
// * interleaved at slots=1: each lane's first small fill is displaced
//   unconsumed and the stream goes dark, costing a few 8 KiB fills per
//   threadblock (~3% of a 1 MiB region at test scale) — comfortably
//   inside the 0.9 band, and the reason slots>=4 must *beat* off below.
// * sequential: adaptive ramps to 24-page (96 KiB + 4 KiB) requests vs
//   the best fixed point's 68 KiB, with ~6 ramp-up misses per 256-page
//   threadblock region; fewer, larger RPCs at the same SSD/PCIe
//   constants put it at or above best-fixed, hence >= 0.95.

#[test]
fn adaptive_reaches_best_fixed_on_sequential_and_spares_random() {
    // The PR-1 tentpole's acceptance table, at test scale.
    let seq = row("sequential");
    assert!(
        seq.adaptive_gbps >= 0.95 * seq.best_fixed_gbps,
        "sequential: adaptive {} must reach best fixed {} ({})",
        seq.adaptive_gbps,
        seq.best_fixed_gbps,
        seq.best_fixed_size,
    );
    let rnd = row("random");
    assert!(
        rnd.adaptive_gbps >= 0.98 * rnd.fixed0_gbps,
        "random: adaptive {} must not regress vs prefetch-off {}",
        rnd.adaptive_gbps,
        rnd.fixed0_gbps
    );
    // Blindly-fixed prefetch DOES regress on random — that contrast is
    // the reason the adaptive engine classifies streams at all.  (Equal
    // only if the sweep's best is prefetch-off itself.)
    assert!(rnd.best_fixed_gbps <= rnd.fixed0_gbps * 1.02);
}

#[test]
fn adaptive_handles_strided_and_interleaved_without_regression() {
    for name in ["strided", "interleaved"] {
        let r = row(name);
        assert!(
            r.adaptive_gbps >= 0.9 * r.fixed0_gbps,
            "{name}: adaptive {} vs prefetch-off {}",
            r.adaptive_gbps,
            r.fixed0_gbps
        );
    }
}

#[test]
fn buffer_pool_lets_interleaved_beat_prefetch_off() {
    // The PR-2 tentpole's acceptance claim: with one slot per substream
    // the interleaved workload stops going dark and *wins* against
    // prefetch-off, instead of merely not losing.
    let inter = row("interleaved");
    let s1 = inter.adaptive_at_slots(1);
    for slots in [4u32, 8] {
        let bw = inter.adaptive_at_slots(slots);
        assert!(
            bw > 1.2 * inter.fixed0_gbps,
            "interleaved slots={slots}: {bw} must beat prefetch-off {} outright",
            inter.fixed0_gbps
        );
        assert!(
            bw > s1,
            "interleaved slots={slots}: {bw} must beat the single-range buffer {s1}"
        );
    }
    // slots=2 covers half the lanes' streams: it must not do worse than
    // the single buffer.
    assert!(inter.adaptive_at_slots(2) >= 0.95 * s1);
}

#[test]
fn extra_slots_leave_single_stream_workloads_unchanged() {
    // sequential has one stream per threadblock (its fill always routes
    // to the same slot); strided locks as sparse and earns no fills at
    // all; random is nearly fill-free (adjacent random offsets can
    // confirm an accidental stream, hence the 2% hedge rather than
    // exact equality).  The slots axis must not move these rows.
    for name in ["sequential", "strided", "random"] {
        let r = row(name);
        let s1 = r.adaptive_at_slots(1);
        for (i, &slots) in fig_adaptive::SLOTS_SWEEP.iter().enumerate() {
            let bw = r.adaptive_slots_gbps[i];
            assert!(
                (0.98..=1.02).contains(&(bw / s1)),
                "{name}: slots={slots} bandwidth {bw} deviates from slots=1 {s1}"
            );
        }
    }
}

#[test]
fn adaptive_micro_runs_are_deterministic() {
    use gpufs_ra::experiments::run_micro;
    use gpufs_ra::workload::Microbench;
    let mut c = cfg();
    c.gpufs.cache_size = 128 * (1 << 20);
    c.gpufs.prefetch_mode = PrefetchMode::Adaptive;
    let m = Microbench::paper(4 * KIB).scaled(16);
    let a = run_micro(&c, &m);
    let b = run_micro(&c, &m);
    assert_eq!(a.end_ns, b.end_ns);
    assert_eq!(a.events, b.events);
    assert_eq!(a.prefetch.prefetched_bytes, b.prefetch.prefetched_bytes);
}

#[test]
fn adaptive_prefetched_bytes_conserve() {
    use gpufs_ra::experiments::run_micro;
    use gpufs_ra::workload::Microbench;
    let mut c = cfg();
    c.gpufs.cache_size = 256 * (1 << 20);
    c.gpufs.prefetch_mode = PrefetchMode::Adaptive;
    let m = Microbench::paper(4 * KIB).scaled(16);
    let r = run_micro(&c, &m);
    assert!(r.prefetch.prefetched_bytes > 0);
    assert_eq!(
        r.prefetch.useful_bytes + r.prefetch.wasted_bytes,
        r.prefetch.prefetched_bytes,
        "useful {} + wasted {} != prefetched {}",
        r.prefetch.useful_bytes,
        r.prefetch.wasted_bytes,
        r.prefetch.prefetched_bytes
    );
}
