//! Remote-storage integration: defaults equivalence, deterministic
//! fault replay, retry/timeout edges (no double delivery, errors
//! surfaced), the adaptive controller's acceptance bands, and the live
//! remote tier's positional checksum.

use gpufs_ra::config::{RemoteConfig, RemoteTier, StackConfig};
use gpufs_ra::engine::EngineKind;
use gpufs_ra::experiments::fig_remote::{self, adaptive_over_bound, adaptive_over_qd1, find};
use gpufs_ra::gpufs::{GpufsSim, RunReport};
use gpufs_ra::oslayer::{
    FaultPlan, IoKind, IoReq, IoSlot, RemoteStats, RemoteStorage, Storage, Vfs,
};
use gpufs_ra::util::bytes::{KIB, MIB};
use gpufs_ra::workload::Microbench;

fn run_micro(c: &StackConfig, m: &Microbench) -> RunReport {
    GpufsSim::new(c, m.files(), m.programs(), 512).run()
}

/// The default config must be event-identical to the pre-remote stack:
/// with `remote.rtt_us = 0` every other remote knob is inert, and the
/// new report counters stay zero.
#[test]
fn defaults_unchanged_by_inert_remote_knobs() {
    let m = Microbench::paper(4 * KIB).scaled(32);
    let base = StackConfig::k40c_p3700();
    let a = run_micro(&base, &m);
    let mut c = base.clone();
    c.set("remote.gbps", "9.9").unwrap();
    c.set("remote.max_inflight", "4").unwrap();
    c.set("remote.fault_seed", "77").unwrap();
    c.validate().unwrap();
    let b = run_micro(&c, &m);
    assert_eq!(a.end_ns, b.end_ns, "inert remote knobs changed timing");
    assert_eq!(a.events, b.events, "inert remote knobs changed the event stream");
    assert_eq!(a.bytes, b.bytes);
    assert_eq!(a.io.retries, 0);
    assert_eq!(a.io.timeouts, 0);
    assert_eq!(a.io.remote, RemoteStats::default());
}

/// The same `remote.fault_seed` must replay the identical event stream
/// — and the faulted run still delivers every byte exactly once (late
/// originals of retried requests are ghosts, never a second delivery).
#[test]
fn fault_seed_replays_identically_no_double_delivery() {
    let m = Microbench::paper(4 * KIB).scaled(32);
    let mut c = StackConfig::k40c_p3700();
    c.set("remote.rtt_us", "1000").unwrap();
    c.set("remote.fault_seed", "7").unwrap();
    c.validate().unwrap();
    let a = run_micro(&c, &m);
    let b = run_micro(&c, &m);
    assert_eq!(a.end_ns, b.end_ns, "same fault_seed, different timing");
    assert_eq!(a.events, b.events, "same fault_seed, different event stream");
    assert_eq!(a.io.retries, b.io.retries);
    assert_eq!(a.io.timeouts, b.io.timeouts);
    assert_eq!(a.io.remote, b.io.remote);
    // The seeded schedule (2% drops) fires on this many requests, and
    // every drop is accounted as a timeout plus a retry.
    assert!(a.io.timeouts > 0, "seeded drops never fired");
    assert!(a.io.retries > 0, "dropped requests were not retried");
    // Exactly-once delivery: total delivered bytes are the workload's,
    // not the workload's plus the retried originals.
    assert_eq!(a.bytes, m.n_tbs as u64 * m.stride);
    assert!(a.io.remote.remote_bytes >= a.bytes, "remote moved less than delivered");
}

/// A different seed is a different (but still deterministic) schedule.
#[test]
fn different_fault_seeds_diverge() {
    let m = Microbench::paper(4 * KIB).scaled(32);
    let mut c = StackConfig::k40c_p3700();
    c.set("remote.rtt_us", "1000").unwrap();
    c.set("remote.fault_seed", "7").unwrap();
    c.validate().unwrap();
    let a = run_micro(&c, &m);
    c.set("remote.fault_seed", "8").unwrap();
    let b = run_micro(&c, &m);
    assert_ne!(
        (a.end_ns, a.io.retries),
        (b.end_ns, b.io.retries),
        "different fault seeds replayed the same schedule"
    );
}

fn remote_cfg(rtt_us: u64) -> RemoteConfig {
    RemoteConfig {
        rtt_us,
        gbps: 1.2,
        max_inflight: 8,
        fault_seed: 0,
        tier: RemoteTier::None,
    }
}

fn sim_remote(rtt_us: u64) -> RemoteStorage {
    let c = StackConfig::k40c_p3700();
    let vfs = Vfs::new(&c.ssd, &c.cpu, &c.readahead, false);
    RemoteStorage::new(vfs, &remote_cfg(rtt_us))
}

/// An injected error-class fault surfaces through both storage paths —
/// `Err` on the blocking read, `IoDone::error` on the submit path (the
/// sim engine panics on it, the live engine's host loop reports it).
#[test]
fn injected_error_surfaces_on_both_paths() {
    let mut st = sim_remote(100);
    let id = st.open(MIB);
    st.set_faults(FaultPlan::with_rates(0xE44, 0, 0, 1000));
    let err = st.read_at(0, id, 0, 4 * KIB, None).unwrap_err();
    assert!(err.contains("injected"), "blocking path lost the error: {err}");

    let mut st = sim_remote(100);
    let id = st.open(MIB);
    st.set_faults(FaultPlan::with_rates(0xE44, 0, 0, 1000));
    let req = IoReq {
        id,
        kind: IoKind::Contig { parts: 1 },
        slots: vec![IoSlot {
            offset: 0,
            len: 4 * KIB,
            buf: None,
        }],
    };
    st.submit(0, req).unwrap();
    let dones = st.complete(1 << 40);
    assert_eq!(dones.len(), 1);
    let e = dones[0].error.as_deref().expect("submit path lost the error");
    assert!(e.contains("injected"), "submit path mangled the error: {e}");
}

/// The headline acceptance bands, at 1/8 paper scale: at 1 ms RTT the
/// adaptive pipeline beats the static qd1 window >= 3x and lands within
/// 20% of the analytic BDP bound; the warmed local tier runs at
/// local-storage speed.
#[test]
fn adaptive_pipeline_and_tier_acceptance() {
    let cfg = StackConfig::k40c_p3700();
    let (rows, _t) = fig_remote::run(&cfg, 8);

    let r31 = adaptive_over_qd1(&rows, 1_000);
    assert!(r31 >= 3.0, "adaptive/qd1 at 1ms RTT = {r31:.2}x, accept >= 3x");
    let rb = adaptive_over_bound(&rows, 1_000);
    assert!(rb >= 0.8, "adaptive at 1ms RTT reached {rb:.2} of the BDP bound");
    // Deeper pipelines should help MORE at higher RTT, not less.
    assert!(
        adaptive_over_qd1(&rows, 10_000) >= r31,
        "adaptive gain shrank as RTT grew"
    );

    // The controller actually deepened the window (p99 of the in-flight
    // depth distribution), and the fault-free sweep retried nothing.
    let ad = find(&rows, "adaptive", 1_000);
    assert!(ad.io.inflight_p99 > 1, "adaptive run never deepened the window");
    assert_eq!(ad.io.retries, 0);
    assert_eq!(ad.io.timeouts, 0);

    // Tier semantics: the cold pass pays the link; the warmed pass is
    // tier-covered (zero link bytes) and runs at local-storage speed.
    let cold = find(&rows, "tier_cold", 1_000);
    let warm = find(&rows, "tier_warm", 1_000);
    let local = find(&rows, "local", 0);
    assert!(cold.remote_bytes > 0);
    assert_eq!(warm.remote_bytes, 0, "warm tier still touched the link");
    assert!(warm.tier_hits > 0);
    assert!(
        warm.gbps >= 0.8 * local.gbps,
        "warm tier {:.3} GB/s vs local {:.3} GB/s",
        warm.gbps,
        local.gbps
    );
    assert!(warm.gbps > cold.gbps, "warm tier no faster than the cold pass");
}

/// Live engine over a remote-shaped file with the local tier: the
/// positional checksum must match the oracle (bytes land exactly once
/// at the right offsets, through real threads and real preads).
#[test]
fn live_remote_tier_micro_checksum() {
    let mut c = StackConfig::k40c_p3700();
    c.engine = EngineKind::Live;
    c.set("remote.rtt_us", "500").unwrap();
    c.set("remote.tier", "local").unwrap();
    c.set("host.io_adaptive", "on").unwrap();
    c.validate().unwrap();
    let m = Microbench {
        n_tbs: 4,
        stride: 256 * KIB,
        io: 4 * KIB,
        file_size: MIB,
        compute_ns_per_read: 0,
    };
    let (run, ok) = gpufs_ra::experiments::live::run_micro_live(&c, &m, None).unwrap();
    assert!(ok, "live remote-tier checksum mismatch vs oracle");
    let r = &run.report;
    assert_eq!(r.bytes, MIB);
    assert!(r.io.remote.remote_bytes > 0, "remote shaping never engaged");
    assert_eq!(r.io.retries, 0, "fault-free run retried");
    assert_eq!(r.io.timeouts, 0, "fault-free run timed out");
}
