//! Observability acceptance tests.
//!
//! Three claims pinned here:
//!
//! 1. **Zero cost when off** — `obs.trace = false` (the default) runs
//!    the EXACT same simulation as a traced run: same virtual end time,
//!    same event count, same request/grant stream.  Span ids live in
//!    plain `Copy` fields, so tracing can never perturb policy.
//! 2. **Span conservation** — every demand read that posts an RPC opens
//!    exactly one request span, and every child interval (queue /
//!    storage / staging / DMA) belongs to an opened span.  This holds
//!    under coalescing (merged preads fan one storage attempt across
//!    many spans), remote faults (retries add attempts, never spans),
//!    and zero-copy staging on the live engine.
//! 3. **Chrome export well-formedness** — the exported trace passes
//!    `validate_chrome` (balanced B/E pairs, per-tid monotone
//!    timestamps), so Perfetto / chrome://tracing load it.

use std::collections::BTreeSet;
use std::path::PathBuf;

use gpufs_ra::config::StackConfig;
use gpufs_ra::engine::EngineKind;
use gpufs_ra::gpufs::live::{self, LiveFile};
use gpufs_ra::gpufs::{GpufsSim, RunReport};
use gpufs_ra::obs::{chrome_trace_json, trace_jsonl, validate_chrome, Stage, TraceEvent};
use gpufs_ra::util::bytes::{KIB, MIB};
use gpufs_ra::workload::Microbench;

/// Conservation over a span stream: each span opens (one Request
/// interval) exactly once, children only reference opened spans, and
/// the open count matches the posted-RPC count.
fn assert_conserved(name: &str, spans: &[TraceEvent], rpc_requests: u64) {
    let mut opened: BTreeSet<u64> = BTreeSet::new();
    for e in spans.iter().filter(|e| e.stage == Stage::Request) {
        assert!(opened.insert(e.span), "{name}: span {} closed twice", e.span);
    }
    assert_eq!(
        opened.len() as u64,
        rpc_requests,
        "{name}: one request span per posted RPC"
    );
    let mut with_storage: BTreeSet<u64> = BTreeSet::new();
    for e in spans {
        assert!(e.t1 >= e.t0, "{name}: negative interval in {:?}", e.stage);
        match e.stage {
            Stage::Queue | Stage::Storage | Stage::Staging | Stage::Dma => {
                assert!(
                    opened.contains(&e.span),
                    "{name}: orphan {:?} for unopened span {}",
                    e.stage,
                    e.span
                );
                if e.stage == Stage::Storage {
                    with_storage.insert(e.span);
                }
            }
            _ => {}
        }
    }
    // Every posted request eventually reached storage (possibly inside
    // a merged group — the host emits one attempt per member request).
    assert_eq!(
        with_storage.len(),
        opened.len(),
        "{name}: spans without a storage attempt"
    );
}

fn traced(mut cfg: StackConfig, m: &Microbench) -> RunReport {
    cfg.set("obs.trace", "true").unwrap();
    cfg.validate().unwrap();
    GpufsSim::new(&cfg, m.files(), m.programs(), 512).run()
}

#[test]
fn sim_trace_off_is_event_identical() {
    for (label, set) in [
        ("off", None),
        ("fixed64k", Some(("gpufs.prefetch_size", "64K"))),
        ("adaptive", Some(("gpufs.prefetch_mode", "adaptive"))),
    ] {
        let mut cfg = StackConfig::k40c_p3700();
        if let Some((k, v)) = set {
            cfg.set(k, v).unwrap();
        }
        let m = Microbench::paper(4 * KIB).scaled(64);
        let run = |c: &StackConfig| GpufsSim::new(c, m.files(), m.programs(), 512)
            .with_grant_log()
            .run();
        let plain = run(&cfg);
        cfg.set("obs.trace", "true").unwrap();
        cfg.validate().unwrap();
        let obs = run(&cfg);
        assert_eq!(plain.end_ns, obs.end_ns, "{label}: tracing changed timing");
        assert_eq!(plain.events, obs.events, "{label}: tracing changed the event stream");
        assert_eq!(plain.bytes, obs.bytes, "{label}: tracing changed delivery");
        assert_eq!(plain.grants, obs.grants, "{label}: tracing changed grants");
        assert!(plain.spans.is_empty(), "{label}: untraced run carried spans");
        assert!(!obs.spans.is_empty(), "{label}: traced run carried no spans");
        assert_conserved(label, &obs.spans, obs.rpc.requests);
    }
}

#[test]
fn sim_spans_conserve_under_coalescing() {
    let mut cfg = StackConfig::k40c_p3700();
    cfg.set("gpufs.rpc_dispatch", "steal").unwrap();
    cfg.set("gpufs.host_coalesce", "adjacent").unwrap();
    cfg.set("gpufs.host_overlap", "true").unwrap();
    let m = Microbench::paper(4 * KIB).scaled(32);
    let r = traced(cfg, &m);
    assert!(r.io.merged_preads > 0, "workload never coalesced — test is vacuous");
    assert_conserved("coalesced", &r.spans, r.rpc.requests);
}

#[test]
fn sim_spans_conserve_under_remote_faults() {
    let mut cfg = StackConfig::k40c_p3700();
    cfg.set("remote.rtt_us", "1000").unwrap();
    cfg.set("remote.fault_seed", "7").unwrap();
    let m = Microbench::paper(4 * KIB).scaled(32);
    let r = traced(cfg, &m);
    assert!(r.io.timeouts > 0, "seeded drops never fired — test is vacuous");
    assert_conserved("faulted", &r.spans, r.rpc.requests);
    // Fault instants surface in the stream (on host tids, span 0).
    let retries = r.spans.iter().filter(|e| e.stage == Stage::Retry).count() as u64;
    let timeouts = r.spans.iter().filter(|e| e.stage == Stage::Timeout).count() as u64;
    assert_eq!(retries, r.io.retries, "retry instants must match the counter");
    assert_eq!(timeouts, r.io.timeouts, "timeout instants must match the counter");
}

#[test]
fn chrome_export_is_well_formed() {
    let mut cfg = StackConfig::k40c_p3700();
    cfg.set("gpufs.prefetch_size", "64K").unwrap();
    let m = Microbench::paper(4 * KIB).scaled(64);
    let r = traced(cfg, &m);
    assert!(!r.spans.is_empty());
    let chrome = chrome_trace_json(&r.spans);
    validate_chrome(&chrome).expect("chrome trace must validate");
    // JSONL is one event per line, loss-free.
    let jsonl = trace_jsonl(&r.spans);
    assert_eq!(jsonl.lines().count(), r.spans.len());
}

// ------------------------------------------------------------- live

fn live_traced(mut cfg: StackConfig, m: &Microbench, tag: &str) -> live::LiveRun {
    cfg.engine = EngineKind::Live;
    cfg.set("obs.trace", "true").unwrap();
    cfg.validate().unwrap();
    let path: PathBuf = std::env::temp_dir().join(format!("gpufs_ra_obs_{tag}.bin"));
    gpufs_ra::experiments::live::ensure_test_file(&path, m.file_size).unwrap();
    let files: Vec<LiveFile> = m
        .files()
        .into_iter()
        .map(|spec| LiveFile {
            path: path.clone(),
            spec,
        })
        .collect();
    live::run(&cfg, &files, m.programs(), 512, false).unwrap()
}

/// The parity workload (disjoint strides, no evictions, coalesce off).
fn parity_micro() -> Microbench {
    Microbench {
        n_tbs: 4,
        stride: 256 * KIB,
        io: 4 * KIB,
        file_size: MIB,
        compute_ns_per_read: 0,
    }
}

#[test]
fn live_spans_conserve_and_grant_streams_match_sim_with_tracing_on() {
    // Tracing on in BOTH engines: span ids ride the grant stream, so
    // sim/live grant parity doubles as cross-engine span determinism.
    let mut cfg = StackConfig::k40c_p3700();
    cfg.set("gpufs.prefetch_size", "64K").unwrap();
    cfg.set("obs.trace", "true").unwrap();
    cfg.validate().unwrap();
    let m = parity_micro();
    let sim = GpufsSim::new(&cfg, m.files(), m.programs(), 512)
        .with_grant_log()
        .run();
    let run = live_traced(cfg, &m, "parity");
    assert_eq!(sim.grants, run.report.grants, "span ids diverged across engines");
    assert_conserved("live_fixed64k", &run.report.spans, run.report.rpc.requests);
    assert_conserved("sim_fixed64k", &sim.spans, sim.rpc.requests);
}

#[test]
fn live_spans_conserve_under_zerocopy_async_staging() {
    let mut cfg = StackConfig::k40c_p3700();
    cfg.set("host.staging", "zerocopy").unwrap();
    cfg.set("host.io_depth", "4").unwrap();
    cfg.set("gpufs.prefetch_size", "64K").unwrap();
    let m = parity_micro();
    let run = live_traced(cfg, &m, "zerocopy");
    assert!(!run.report.spans.is_empty());
    assert_conserved("live_zerocopy", &run.report.spans, run.report.rpc.requests);
    let chrome = chrome_trace_json(&run.report.spans);
    validate_chrome(&chrome).expect("live chrome trace must validate");
}
