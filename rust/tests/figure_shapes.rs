//! Integration tests: every paper figure's SHAPE must hold.
//!
//! These run the experiment harness at reduced scale and assert the
//! qualitative results the paper reports — who wins, roughly by how much,
//! and where the crossovers fall.  Absolute numbers are the calibrated
//! model's; the assertions are deliberately banded.

use gpufs_ra::config::StackConfig;
use gpufs_ra::experiments as exp;
use gpufs_ra::util::bytes::KIB;

const SCALE: u64 = 4;

fn cfg() -> StackConfig {
    StackConfig::k40c_p3700()
}

#[test]
fn motivation_cpu_is_about_4x_gpufs_4k() {
    let (m, _) = exp::motivation::run(&cfg(), SCALE);
    assert!(
        (1.2..=2.2).contains(&m.cpu_gbps),
        "CPU baseline {} GB/s out of band (paper ~1.6)",
        m.cpu_gbps
    );
    assert!(
        (2.5..=6.0).contains(&m.ratio),
        "CPU/GPUfs ratio {} out of band (paper ~4x)",
        m.ratio
    );
}

#[test]
fn fig2_peak_is_64k_and_exceeds_cpu() {
    let (rows, cpu, _) = exp::fig2::run(&cfg(), SCALE);
    let best = rows
        .iter()
        .max_by(|a, b| a.gbps.partial_cmp(&b.gbps).unwrap())
        .unwrap();
    assert_eq!(best.page_size, 64 * KIB, "peak must be at 64K pages");
    assert!(best.gbps > cpu, "64K pages must exceed the CPU baseline");
    // 4K is the worst of the small pages; ≥128K declines from the peak.
    let r4 = &rows[0];
    assert!(r4.gbps < 0.5 * best.gbps);
    let r128 = rows.iter().find(|r| r.page_size == 128 * KIB).unwrap();
    assert!(r128.gbps < 0.7 * best.gbps, "128K cliff missing");
}

#[test]
fn fig3_crossover_at_128k() {
    let (rows, _) = exp::fig3::run(&cfg(), SCALE);
    for r in &rows {
        if r.req < 128 * KIB {
            assert!(
                r.gpu_gbps > 0.9 * r.cpu_gbps,
                "below 128K GPU must be competitive: {} vs {} at {}",
                r.gpu_gbps,
                r.cpu_gbps,
                r.req
            );
        }
    }
    let at128 = rows.iter().find(|r| r.req == 128 * KIB).unwrap();
    assert!(
        at128.gpu_gbps < 0.55 * at128.cpu_gbps,
        "at 128K the CPU must win big (paper: 160% higher): {} vs {}",
        at128.gpu_gbps,
        at128.cpu_gbps
    );
}

#[test]
fn fig5_replay_matches_below_128k_and_beats_gpu_at_128k() {
    let (rows, _) = exp::fig5::run(&cfg(), SCALE);
    for r in &rows {
        if r.req < 128 * KIB {
            let ratio = r.gpu_gbps / r.replay_gbps;
            assert!(
                (0.75..=1.25).contains(&ratio),
                "below 128K replay ~ GPU: ratio {ratio} at {}",
                r.req
            );
        }
    }
    let at128 = rows.iter().find(|r| r.req == 128 * KIB).unwrap();
    assert!(at128.gpu_gbps < 0.6 * at128.replay_gbps);
}

#[test]
fn fig6_threads_2_3_starve() {
    let (rows, _) = exp::fig6::run(&cfg(), SCALE);
    for r in &rows {
        assert!(r.spins[0] < 100, "thread 0 must start immediately");
        assert!(r.spins[1] < 100, "thread 1 must start immediately");
        assert!(
            r.spins[2] > 100 * r.spins[0].max(1),
            "thread 2 must starve at page size {}",
            r.page_size
        );
        assert!(r.spins[3] > 100 * r.spins[0].max(1));
    }
}

#[test]
fn fig7_pcie_bandwidth_monotone_in_page_size() {
    let (rows, _) = exp::fig7::run(&cfg(), SCALE);
    for w in rows.windows(2) {
        assert!(
            w[1].gbps > w[0].gbps * 0.95,
            "Fig 7 must be (near-)monotone: {} then {}",
            w[0].gbps,
            w[1].gbps
        );
    }
    assert!(rows.last().unwrap().gbps > 5.0 * rows[0].gbps);
}

#[test]
fn fig9_prefetcher_recovers_large_page_performance() {
    let (rows, _) = exp::fig9::run(&cfg(), SCALE);
    let best_orig = rows.iter().map(|r| r.original_gbps).fold(0.0, f64::max);
    let best_pf = rows.iter().map(|r| r.prefetcher_gbps).fold(0.0, f64::max);
    // Paper: within 20% of the best original configuration.
    assert!(
        best_pf > 0.75 * best_orig,
        "prefetcher best {best_pf} vs original best {best_orig}"
    );
    // And ~2x the original at the same 4K pages (we allow 1.8x..6x).
    let orig_4k = rows[0].original_gbps;
    let pf_64k = rows
        .iter()
        .find(|r| r.x_bytes == 64 * KIB)
        .unwrap()
        .prefetcher_gbps;
    let speedup = pf_64k / orig_4k;
    assert!(
        (1.8..=6.0).contains(&speedup),
        "prefetcher speedup {speedup} out of band (paper ~2x)"
    );
    // The prefetcher's own 128K cliff: prefetch sizes that push the
    // request past the Linux readahead window lose the async tail.
    let pf_at_64k = pf_64k;
    let pf_at_256k = rows
        .iter()
        .find(|r| r.x_bytes == 256 * KIB)
        .unwrap()
        .prefetcher_gbps;
    assert!(
        pf_at_256k < pf_at_64k,
        "request > ra_max must hurt: {pf_at_256k} vs {pf_at_64k}"
    );
}

#[test]
fn fig10_ordering_and_magnitude() {
    let (r, _) = exp::fig10::run(&cfg(), SCALE);
    assert!(r.new_replacement_gbps > 3.0 * r.prefetcher_gbps, "paper ~6x");
    assert!(r.new_replacement_gbps > 4.0 * r.original_gbps, "paper ~8x");
    assert!(r.prefetcher_gbps >= 0.9 * r.original_gbps);
}

#[test]
fn mosaic_small_pages_win_for_random_access() {
    let (m, _) = exp::mosaic::run(&cfg(), 16);
    assert!(
        m.speedup_4k > 1.0,
        "4K pages must beat 64K on random access: {}",
        m.speedup_4k
    );
}

#[test]
fn apps_small_mode_geomeans() {
    use gpufs_ra::util::stats::geomean;
    let (rows, _, _) = exp::apps::run(&cfg(), 16, exp::apps::Mode::Small);
    assert_eq!(rows.len(), 14);
    let speedup = |name: &str| -> Vec<f64> {
        rows.iter()
            .map(|r| {
                let base = r.e2e.iter().find(|(n, _)| *n == "orig4k").unwrap().1 as f64;
                let t = r.e2e.iter().find(|(n, _)| *n == name).unwrap().1 as f64;
                base / t
            })
            .collect()
    };
    let pf = geomean(&speedup("prefetch"));
    let cpu = geomean(&speedup("cpu"));
    // Paper: prefetcher 3x geomean over original, 1.5x over CPU.
    assert!((1.7..=4.5).contains(&pf), "prefetch geomean {pf} (paper ~3x)");
    assert!(pf > cpu, "prefetcher must beat the CPU baseline end-to-end");
    // I/O bandwidth: prefetcher ~4x orig, ~2x CPU (banded).
    let bw = |name: &str| -> Vec<f64> {
        rows.iter()
            .map(|r| r.io_bw.iter().find(|(n, _)| *n == name).unwrap().1)
            .collect()
    };
    let bw_ratio = geomean(&bw("prefetch")) / geomean(&bw("orig4k"));
    assert!((1.8..=5.0).contains(&bw_ratio), "bw ratio {bw_ratio} (paper ~4x)");
    let bw_cpu = geomean(&bw("prefetch")) / geomean(&bw("cpu"));
    assert!(bw_cpu > 1.1, "prefetch I/O bw must beat CPU: {bw_cpu} (paper ~2x)");
}

#[test]
fn apps_large_mode_replacement_wins() {
    use gpufs_ra::util::stats::geomean;
    let (rows, _, _) = exp::apps::run(&cfg(), 16, exp::apps::Mode::Large);
    let bw = |name: &str| -> Vec<f64> {
        rows.iter()
            .map(|r| r.io_bw.iter().find(|(n, _)| *n == name).unwrap().1)
            .collect()
    };
    let newrepl = geomean(&bw("newrepl"));
    let prefetch = geomean(&bw("prefetch"));
    let orig = geomean(&bw("orig4k"));
    // Paper: ~6x over prefetcher-only, ~8x over original (banded).
    assert!(newrepl > 2.5 * prefetch, "{newrepl} vs prefetch {prefetch}");
    assert!(newrepl > 3.5 * orig, "{newrepl} vs orig {orig}");
}

#[test]
fn determinism_across_identical_runs() {
    let a = exp::motivation::run(&cfg(), 8).0;
    let b = exp::motivation::run(&cfg(), 8).0;
    assert_eq!(a.cpu_gbps, b.cpu_gbps);
    assert_eq!(a.gpufs_gbps, b.gpufs_gbps);
}
