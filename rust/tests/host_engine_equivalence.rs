//! Default-knob regression anchor for the HostEngine refactor.
//!
//! With `rpc_dispatch = static`, `host_coalesce = off`, `host_overlap =
//! off` the engine must be *event-identical* to the pre-refactor host
//! loop: the same replies at the same times, the same poll-pass schedule,
//! the same spin/served/busy accounting, and the same OS-layer / SSD /
//! DMA traffic.  Since that implementation is gone from the tree, a
//! verbatim copy of it (the PR 2 state of `RpcQueue` plus the
//! `GpufsSim::post_request`/`host_scan` bodies, lifted out of the
//! simulator) lives here, and both engines are driven open-loop through
//! the same scripted request schedules over real `Vfs` + `PcieDma`
//! instances.

use gpufs_ra::config::StackConfig;
use gpufs_ra::gpufs::host::{HostEngine, HostEvent};
use gpufs_ra::gpufs::rpc::Request;
use gpufs_ra::gpufs::TraceEntry;
use gpufs_ra::oslayer::FileId;
use gpufs_ra::sim::{Calendar, Time};
use gpufs_ra::util::bytes::{GIB, KIB, MIB};
use gpufs_ra::util::prng::Prng;

/// Verbatim pre-refactor implementation (PR 2 state of
/// `rust/src/gpufs/rpc.rs` + the host half of `rust/src/gpufs/mod.rs`).
mod legacy {
    use gpufs_ra::config::StackConfig;
    use gpufs_ra::device::pcie::PcieDma;
    use gpufs_ra::gpufs::rpc::Request;
    use gpufs_ra::oslayer::Vfs;
    use gpufs_ra::sim::Time;

    #[derive(Debug, Default, Clone)]
    pub struct HostThreadStats {
        pub spins_before_first: u64,
        pub spins_total: u64,
        pub served: u64,
        pub bytes: u64,
        pub busy_ns: Time,
        seen_first: bool,
    }

    #[derive(Debug)]
    pub struct RpcQueue {
        slots: Vec<Option<Request>>,
        per_thread: u32,
        pending: Vec<u32>,
        pub threads: Vec<HostThreadStats>,
    }

    impl RpcQueue {
        pub fn new(n_slots: u32, host_threads: u32) -> Self {
            assert!(n_slots > 0 && host_threads > 0);
            assert_eq!(n_slots % host_threads, 0);
            RpcQueue {
                slots: vec![None; n_slots as usize],
                per_thread: n_slots / host_threads,
                pending: vec![0; host_threads as usize],
                threads: vec![HostThreadStats::default(); host_threads as usize],
            }
        }

        pub fn n_slots(&self) -> u32 {
            self.slots.len() as u32
        }

        pub fn slots_per_thread(&self) -> u32 {
            self.per_thread
        }

        pub fn slot_of(&self, tb: u32) -> u32 {
            tb % self.n_slots()
        }

        pub fn thread_of_slot(&self, slot: u32) -> u32 {
            slot / self.per_thread
        }

        pub fn post(&mut self, req: Request) -> u32 {
            let slot = self.slot_of(req.tb) as usize;
            assert!(self.slots[slot].is_none(), "slot {slot} busy");
            self.slots[slot] = Some(req);
            let th = self.thread_of_slot(slot as u32);
            self.pending[th as usize] += 1;
            th
        }

        pub fn has_pending(&self, t: u32) -> bool {
            self.pending[t as usize] > 0
        }

        pub fn credit_spins(&mut self, t: u32, n: u64) {
            let st = &mut self.threads[t as usize];
            st.spins_total += n;
            if !st.seen_first {
                st.spins_before_first += n;
            }
        }

        pub fn scan(&mut self, t: u32, now: Time) -> Vec<Request> {
            let mut found = Vec::new();
            if self.pending[t as usize] > 0 {
                found.reserve(self.pending[t as usize] as usize);
                let lo = (t * self.per_thread) as usize;
                let hi = lo + self.per_thread as usize;
                for s in lo..hi {
                    if let Some(req) = self.slots[s] {
                        if req.posted_at <= now {
                            found.push(req);
                            self.slots[s] = None;
                            self.pending[t as usize] -= 1;
                        }
                    }
                }
            }
            let st = &mut self.threads[t as usize];
            if found.is_empty() {
                st.spins_total += 1;
                if !st.seen_first {
                    st.spins_before_first += 1;
                }
            } else {
                st.seen_first = true;
                st.served += found.len() as u64;
            }
            found
        }
    }

    /// One scheduling instruction the pre-refactor host loop would have
    /// put on the simulator calendar.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum Out {
        Reply { tb: u32, at: Time },
        Scan { thread: u32, at: Time },
    }

    /// The pre-refactor host half of `GpufsSim`, with calendar calls
    /// replaced by returned [`Out`] instructions (same order).
    pub struct LegacyHost {
        pub rpc: RpcQueue,
        pub vfs: Vfs,
        pub dma: PcieDma,
        parked: Vec<Option<Time>>,
        page_size: u64,
        stage_page_ns: u64,
        max_batch_pages: u32,
        poll_slot_ns: u64,
        io_only: bool,
    }

    impl LegacyHost {
        pub fn new(cfg: &StackConfig) -> Self {
            LegacyHost {
                rpc: RpcQueue::new(cfg.gpufs.rpc_slots, cfg.gpufs.host_threads),
                vfs: Vfs::new(&cfg.ssd, &cfg.cpu, &cfg.readahead, cfg.ramfs),
                dma: PcieDma::new(&cfg.pcie),
                parked: vec![None; cfg.gpufs.host_threads as usize],
                page_size: cfg.gpufs.page_size,
                stage_page_ns: cfg.pcie.stage_page_ns,
                max_batch_pages: cfg.gpufs.max_batch_pages,
                poll_slot_ns: cfg.cpu.poll_slot_ns,
                io_only: cfg.no_pcie,
            }
        }

        fn scan_ns(&self) -> Time {
            self.rpc.slots_per_thread() as Time * self.poll_slot_ns as Time
        }

        /// Verbatim `GpufsSim::post_request` (the queue/wakeup half).
        pub fn post(&mut self, req: Request, now: Time) -> Option<(u32, Time)> {
            let t = req.posted_at;
            let th = self.rpc.post(req);
            if let Some(since) = self.parked[th as usize].take() {
                let scan_ns = self.scan_ns();
                let wake = t.max(now) + scan_ns;
                self.rpc
                    .credit_spins(th, (wake.saturating_sub(since)) / scan_ns.max(1));
                return Some((th, wake));
            }
            None
        }

        /// Verbatim `GpufsSim::host_scan`.
        pub fn scan(
            &mut self,
            tid: u32,
            now: Time,
            all_done: bool,
            trace: &mut Vec<(u32, u64, u64, Time)>,
        ) -> Vec<Out> {
            let reqs = self.rpc.scan(tid, now);
            let scan_ns = self.scan_ns();
            if reqs.is_empty() {
                if all_done {
                    return Vec::new();
                }
                if self.rpc.has_pending(tid) {
                    return vec![Out::Scan {
                        thread: tid,
                        at: now + scan_ns,
                    }];
                }
                self.parked[tid as usize] = Some(now);
                return Vec::new();
            }
            let mut out = Vec::new();
            let mut t = now + scan_ns;
            let ps = self.page_size;
            for req in reqs {
                let total = req.demand_bytes + req.prefetch_bytes;
                if req.prefetch_bytes > 0 {
                    t = self.vfs.pread(t, req.file, req.offset, total).done;
                } else {
                    let mut off = req.offset;
                    let end = req.offset + req.demand_bytes;
                    while off < end {
                        let chunk = ps.min(end - off);
                        t = self.vfs.pread(t, req.file, off, chunk).done;
                        off += chunk;
                    }
                }
                trace.push((tid, req.offset, total, t));
                let st = &mut self.rpc.threads[tid as usize];
                st.bytes += total;
                let reply_at = if self.io_only {
                    t
                } else {
                    let n_pages = total.div_ceil(ps);
                    t += n_pages * self.stage_page_ns as Time;
                    let max_batch = self.max_batch_pages as u64 * ps;
                    let mut remaining = total;
                    let mut arrive = t;
                    while remaining > 0 {
                        let chunk = remaining.min(max_batch);
                        arrive = self.dma.h2d(t, chunk);
                        remaining -= chunk;
                    }
                    arrive
                };
                out.push(Out::Reply {
                    tb: req.tb,
                    at: reply_at.max(now),
                });
            }
            let st = &mut self.rpc.threads[tid as usize];
            st.busy_ns += t - now;
            out.push(Out::Scan { thread: tid, at: t });
            out
        }
    }
}

// ------------------------------------------------------------- driver

/// A scripted post: the driver invokes `post` at `at` (the TbRun event
/// time); `req.posted_at >= at` (threadblock-local clocks run ahead).
#[derive(Debug, Clone, Copy)]
struct ScriptPost {
    at: Time,
    req: Request,
}

/// Everything observable about one open-loop drive: the exact event
/// stream plus final accounting.
#[derive(Debug, PartialEq)]
struct Outcome {
    /// ("reply"|"scan", id, time) in firing order.
    log: Vec<(&'static str, u32, Time)>,
    trace: Vec<(u32, u64, u64, Time)>,
    /// Per thread: (spins_before_first, spins_total, served, bytes, busy).
    threads: Vec<(u64, u64, u64, u64, Time)>,
    vfs: (u64, u64, Time, u64, u64),
    ssd: (u64, u64),
    dma: (u64, u64),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Ev {
    Post(usize),
    Scan(u32),
    Stage(u32),
}

fn run_new(cfg: &StackConfig, files: &[u64], posts: &[ScriptPost]) -> Outcome {
    let threads = cfg.gpufs.host_threads;
    let mut eng = HostEngine::new(cfg);
    for &size in files {
        eng.open(size);
    }
    let mut cal: Calendar<Ev> = Calendar::new();
    for (i, p) in posts.iter().enumerate() {
        cal.schedule_at(p.at, Ev::Post(i));
    }
    for t in 0..threads {
        cal.schedule_at(200 * t as Time, Ev::Scan(t));
    }
    let mut log = Vec::new();
    let mut trace_entries: Vec<TraceEntry> = Vec::new();
    while let Some((now, ev)) = cal.pop() {
        match ev {
            Ev::Post(i) => {
                if let Some((th, wake)) = eng.post(posts[i].req, now) {
                    cal.schedule_at(wake, Ev::Scan(th));
                }
            }
            Ev::Scan(t) => {
                for he in eng.scan(t, now, false, Some(&mut trace_entries)) {
                    match he {
                        HostEvent::Reply { tb, at } => log.push(("reply", tb, at)),
                        HostEvent::Stage { thread, at } => {
                            cal.schedule_at(at, Ev::Stage(thread));
                        }
                        HostEvent::Scan { thread, at } => {
                            log.push(("scan", thread, at));
                            cal.schedule_at(at, Ev::Scan(thread));
                        }
                        HostEvent::IoDone { .. } => {
                            unreachable!("default-knob engine never submits async I/O")
                        }
                    }
                }
            }
            Ev::Stage(thread) => {
                for (tb, at) in eng.stage(thread, now) {
                    log.push(("reply", tb, at.max(now)));
                }
            }
        }
    }
    Outcome {
        log,
        trace: trace_entries
            .iter()
            .map(|e| (e.thread, e.offset, e.bytes, e.at))
            .collect(),
        threads: eng
            .rpc
            .threads
            .iter()
            .map(|h| (h.spins_before_first, h.spins_total, h.served, h.bytes, h.busy_ns))
            .collect(),
        vfs: (
            eng.vfs.stats.preads,
            eng.vfs.stats.bytes,
            eng.vfs.stats.blocked_ns,
            eng.vfs.stats.hits,
            eng.vfs.stats.misses,
        ),
        ssd: (eng.vfs.ssd.bytes_read(), eng.vfs.ssd.commands()),
        dma: (eng.dma.bytes_moved(), eng.dma.transfers()),
    }
}

fn run_legacy(cfg: &StackConfig, files: &[u64], posts: &[ScriptPost]) -> Outcome {
    let threads = cfg.gpufs.host_threads;
    let mut eng = legacy::LegacyHost::new(cfg);
    for &size in files {
        eng.vfs.open(size);
    }
    let mut cal: Calendar<Ev> = Calendar::new();
    for (i, p) in posts.iter().enumerate() {
        cal.schedule_at(p.at, Ev::Post(i));
    }
    for t in 0..threads {
        cal.schedule_at(200 * t as Time, Ev::Scan(t));
    }
    let mut log = Vec::new();
    let mut trace = Vec::new();
    while let Some((now, ev)) = cal.pop() {
        match ev {
            Ev::Post(i) => {
                if let Some((th, wake)) = eng.post(posts[i].req, now) {
                    cal.schedule_at(wake, Ev::Scan(th));
                }
            }
            Ev::Scan(t) => {
                for out in eng.scan(t, now, false, &mut trace) {
                    match out {
                        legacy::Out::Reply { tb, at } => log.push(("reply", tb, at)),
                        legacy::Out::Scan { thread, at } => {
                            log.push(("scan", thread, at));
                            cal.schedule_at(at, Ev::Scan(thread));
                        }
                    }
                }
            }
            Ev::Stage(_) => unreachable!("legacy host never stages"),
        }
    }
    Outcome {
        log,
        trace,
        threads: eng
            .rpc
            .threads
            .iter()
            .map(|h| (h.spins_before_first, h.spins_total, h.served, h.bytes, h.busy_ns))
            .collect(),
        vfs: (
            eng.vfs.stats.preads,
            eng.vfs.stats.bytes,
            eng.vfs.stats.blocked_ns,
            eng.vfs.stats.hits,
            eng.vfs.stats.misses,
        ),
        ssd: (eng.vfs.ssd.bytes_read(), eng.vfs.ssd.commands()),
        dma: (eng.dma.bytes_moved(), eng.dma.transfers()),
    }
}

fn assert_equivalent(name: &str, cfg: &StackConfig, files: &[u64], posts: &[ScriptPost]) {
    let new = run_new(cfg, files, posts);
    let old = run_legacy(cfg, files, posts);
    assert_eq!(
        new, old,
        "{name}: default-knob HostEngine diverged from the legacy host loop"
    );
    // Sanity: the drive actually served everything it posted.
    let replies = new.log.iter().filter(|(k, _, _)| *k == "reply").count();
    assert_eq!(replies, posts.len(), "{name}: not every post was served");
}

// ------------------------------------------------------------ scripts

fn req(tb: u32, file: usize, offset: u64, demand: u64, prefetch: u64, posted_at: Time) -> Request {
    Request {
        tb,
        file: FileId(file),
        offset,
        demand_bytes: demand,
        prefetch_bytes: prefetch,
        prefetch_back: false,
        stream: None,
        posted_at,
        span: 0,
    }
}

/// The Fig 6 shape: one occupancy wave of 60 threadblocks posting 64 KiB
/// demand reads within ~2 µs, then a second wave much later.
fn first_wave_script(page: u64) -> Vec<ScriptPost> {
    let mut rng = Prng::new(0xF16_6);
    let mut posts = Vec::new();
    for tb in 0..60u32 {
        let at = rng.gen_range(2_000);
        posts.push(ScriptPost {
            at,
            req: req(tb, 0, tb as u64 * 2 * MIB, page, 0, at),
        });
    }
    for tb in 60..120u32 {
        let at = 30_000_000 + rng.gen_range(2_000);
        posts.push(ScriptPost {
            at,
            req: req(tb, 0, tb as u64 * 2 * MIB, page, 0, at),
        });
    }
    posts
}

#[test]
fn first_wave_64k_is_event_identical() {
    let mut cfg = StackConfig::k40c_p3700();
    cfg.gpufs.page_size = 64 * KIB;
    assert_equivalent("first_wave_64k", &cfg, &[10 * GIB], &first_wave_script(64 * KIB));
}

#[test]
fn first_wave_io_only_is_event_identical() {
    let mut cfg = StackConfig::k40c_p3700();
    cfg.gpufs.page_size = 64 * KIB;
    cfg.no_pcie = true;
    assert_equivalent(
        "first_wave_io_only",
        &cfg,
        &[10 * GIB],
        &first_wave_script(64 * KIB),
    );
}

#[test]
fn explicit_io_depth_1_copy_staging_is_event_identical() {
    // The async submission window is a strict opt-in: spelling out the
    // defaults (`host.io_depth = 1`, `host.staging = copy`) must route
    // through the very same serial loop, event for event — the
    // structural guarantee that PR 7 left the default path untouched.
    let mut cfg = StackConfig::k40c_p3700();
    cfg.set("host.io_depth", "1").unwrap();
    cfg.set("host.staging", "copy").unwrap();
    cfg.gpufs.page_size = 64 * KIB;
    assert_equivalent(
        "explicit_defaults",
        &cfg,
        &[10 * GIB],
        &first_wave_script(64 * KIB),
    );
}

#[test]
fn prefetch_inflated_stream_is_event_identical() {
    // 4 KiB demand + 64 KiB prefetch per request, sequential per tb:
    // exercises the single-pread path, staging of 17 pages, and DMA
    // batching, over several service rounds.
    let cfg = StackConfig::k40c_p3700();
    let mut rng = Prng::new(0x9E1F);
    let mut posts = Vec::new();
    for round in 0..3u64 {
        for tb in 0..40u32 {
            let at = round * 40_000_000 + rng.gen_range(1_000_000);
            posts.push(ScriptPost {
                at,
                req: req(
                    tb,
                    0,
                    tb as u64 * 8 * MIB + round * 68 * KIB,
                    4 * KIB,
                    64 * KIB,
                    at,
                ),
            });
        }
    }
    assert_equivalent("prefetch_stream", &cfg, &[10 * GIB], &posts);
}

#[test]
fn multi_page_demand_and_multi_file_are_event_identical() {
    // Demand-only requests spanning several GPUfs pages (the per-page
    // pread loop) spread over two files, with stragglers posted into the
    // visible future so rescans trigger.
    let cfg = StackConfig::k40c_p3700();
    let mut rng = Prng::new(0xABCD);
    let mut posts = Vec::new();
    for tb in 0..64u32 {
        let at = rng.gen_range(4_000);
        let file = (tb % 2) as usize;
        let pages = 1 + (tb % 3) as u64;
        posts.push(ScriptPost {
            at,
            req: req(
                tb,
                file,
                (tb as u64) * MIB + rng.gen_range(64) * 16 * KIB,
                pages * 4 * KIB,
                0,
                at + rng.gen_range(6_000),
            ),
        });
    }
    assert_equivalent("multi_page_two_files", &cfg, &[GIB, GIB], &posts);
}

#[test]
fn parked_thread_wakeups_are_event_identical() {
    // Long quiet gaps force every thread to park; each isolated post must
    // wake exactly the owner with the same credited spins.
    let cfg = StackConfig::k40c_p3700();
    let mut posts = Vec::new();
    for (i, tb) in [3u32, 40, 70, 100, 7, 44].iter().enumerate() {
        let at = i as Time * 5_000_000;
        posts.push(ScriptPost {
            at,
            req: req(*tb, 0, *tb as u64 * MIB, 4 * KIB, 0, at),
        });
    }
    assert_equivalent("parked_wakeups", &cfg, &[GIB], &posts);
}
