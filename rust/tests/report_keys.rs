//! Backward-compatibility pin on the `micro` command's flat key set.
//!
//! The PR that nested `RunReport` into per-subsystem sections
//! (`io` / `xfer` / `rpc`) kept the user-visible `--json` keys flat via
//! [`RunReport::micro_rows`].  This test pins the exact key lists —
//! name AND order — for both engines, so future report refactors cannot
//! silently break `--json` consumers.

use gpufs_ra::config::StackConfig;
use gpufs_ra::experiments::run_micro;
use gpufs_ra::util::bytes::KIB;
use gpufs_ra::workload::Microbench;

#[test]
fn micro_row_keys_are_pinned() {
    let m = Microbench::paper(4 * KIB).scaled(64);
    let r = run_micro(&StackConfig::k40c_p3700(), &m);

    let sim: Vec<&str> = r.micro_rows(false).iter().map(|(k, _)| *k).collect();
    assert_eq!(
        sim,
        [
            "bytes",
            "time_ms",
            "bandwidth_gbps",
            "rpc_requests",
            "host_preads",
            "merged_preads",
            "prefetch_buffer_hits",
            "prefetch_bytes_total",
            "prefetch_bytes_wasted",
            "cache_evictions",
            "local_recycles",
            "gpu_cache_hit_rate",
            "ssd_bytes",
            "dma_transfers",
            "inflight_p99",
            "retries",
            "timeouts",
            "sim_events",
        ],
        "sim micro --json key set changed"
    );

    // The live table is the sim set minus sim-only counters (main.rs
    // appends the checksum row itself).
    let live: Vec<&str> = r.micro_rows(true).iter().map(|(k, _)| *k).collect();
    assert_eq!(
        live,
        [
            "bytes",
            "time_ms",
            "bandwidth_gbps",
            "rpc_requests",
            "host_preads",
            "merged_preads",
            "prefetch_buffer_hits",
            "prefetch_bytes_total",
            "gpu_cache_hit_rate",
            "inflight_p99",
            "retries",
            "timeouts",
        ],
        "live micro --json key set changed"
    );

    // Spot-check the value formatting contract survives the refactor.
    let find = |k: &str| {
        r.micro_rows(false)
            .into_iter()
            .find(|(key, _)| *key == k)
            .map(|(_, v)| v)
            .unwrap()
    };
    assert!(find("bandwidth_gbps").parse::<f64>().is_ok());
    assert_eq!(find("rpc_requests"), r.rpc.requests.to_string());
    assert_eq!(find("sim_events"), r.events.to_string());
}
