//! Multi-tenant service acceptance tests.
//!
//! Three claims pinned here:
//!
//! 1. **Event identity** — `service.max_jobs = 1`, `budget = shared`,
//!    `tenant_aware = off`: one job submitted through the service runs
//!    the EXACT same simulation as the pre-service single-job path
//!    (same virtual end time, same event count, same request/grant
//!    stream, same host-thread accounting).  The service may only add
//!    bookkeeping, never behaviour, until its knobs are turned.
//! 2. **Isolation** — the `fig_service` thrash mix at 4 concurrent
//!    tenants: `partitioned` budget + `tenant_aware` replacement keeps
//!    every tenant's p99 gread latency within 2× its solo run, while the
//!    naive mode (shared budget, stock GlobalLra) starves at least one
//!    tenant beyond that bound.
//! 3. **Both engines** — the same service plan runs live (real worker
//!    and host threads, real files): per-tenant checksums verify against
//!    oracles, per-tenant accounting is complete, and `max_jobs`
//!    admission queues jobs in wall-clock time too.

use gpufs_ra::config::{ServiceBudget, StackConfig};
use gpufs_ra::engine::EngineKind;
use gpufs_ra::experiments::fig_service;
use gpufs_ra::experiments::live::ensure_test_file_seeded;
use gpufs_ra::gpufs::live::LiveFile;
use gpufs_ra::gpufs::rpc::HostThreadStats;
use gpufs_ra::gpufs::{FileSpec, GpufsSim, Gread, TbProgram};
use gpufs_ra::oslayer::FileId;
use gpufs_ra::service::{JobSpec, LiveJobSpec, Service};
use gpufs_ra::util::bytes::{KIB, MIB};
use gpufs_ra::workload::Microbench;

/// Host-thread accounting signature (HostThreadStats has no PartialEq).
fn host_sig(h: &[HostThreadStats]) -> Vec<(u64, u64, u64, u64, u64, u64, u64)> {
    h.iter()
        .map(|t| {
            (
                t.spins_before_first,
                t.spins_total,
                t.served,
                t.stolen,
                t.bytes,
                t.queue_delay_sum,
                t.queue_delay_max,
            )
        })
        .collect()
}

fn micro_job(m: &Microbench) -> JobSpec {
    JobSpec {
        tenant: "solo".into(),
        files: m.files(),
        programs: m.programs(),
    }
}

#[test]
fn single_job_default_service_is_event_identical() {
    // Prefetch-off, fixed-64K, and adaptive configs all pin identical.
    let m = Microbench {
        n_tbs: 8,
        stride: 256 * KIB,
        io: 4 * KIB,
        file_size: 4 * MIB,
        compute_ns_per_read: 0,
    };
    for (label, set) in [
        ("off", None),
        ("fixed64k", Some(("gpufs.prefetch_size", "64K"))),
        ("adaptive", Some(("gpufs.prefetch_mode", "adaptive"))),
    ] {
        let mut cfg = StackConfig::k40c_p3700();
        cfg.gpufs.cache_size = 64 * MIB;
        if let Some((k, v)) = set {
            cfg.set(k, v).unwrap();
        }
        assert_eq!(cfg.service.max_jobs, 1, "default service config");
        assert_eq!(cfg.service.budget, ServiceBudget::Shared);

        let direct = GpufsSim::new(&cfg, m.files(), m.programs(), 512)
            .with_grant_log()
            .run();
        let via = Service::new(&cfg)
            .unwrap()
            .run_sim_with_grants(&[micro_job(&m)])
            .unwrap()
            .report;

        assert_eq!(direct.end_ns, via.end_ns, "{label}: virtual end time");
        assert_eq!(direct.events, via.events, "{label}: event count");
        assert_eq!(direct.bytes, via.bytes, "{label}: delivered bytes");
        assert_eq!(direct.grants, via.grants, "{label}: grant stream");
        assert_eq!(direct.io.preads, via.io.preads, "{label}: pread count");
        assert_eq!(direct.io.ssd_cmds, via.io.ssd_cmds, "{label}: ssd commands");
        assert_eq!(direct.rpc.requests, via.rpc.requests, "{label}: rpc count");
        assert_eq!(
            host_sig(&direct.host),
            host_sig(&via.host),
            "{label}: host accounting"
        );
        assert_eq!(direct.cache.allocs, via.cache.allocs, "{label}: allocs");
        assert_eq!(
            direct.cache.global_evictions, via.cache.global_evictions,
            "{label}: evictions"
        );
        // The service path additionally accounts the job.
        assert!(direct.tenants.is_empty(), "plain runs carry no tenants");
        assert_eq!(via.tenants.len(), 1);
        assert_eq!(via.tenants[0].bytes, via.bytes);
        assert_eq!(via.tenants[0].admitted_ns, 0);
        assert_eq!(via.tenants[0].done_ns, via.end_ns);
        assert_eq!(
            via.tenants[0].latency_ns.count(),
            8 * 64,
            "{label}: one latency sample per gread"
        );
    }
}

#[test]
fn four_tenant_thrash_isolated_protects_every_tenant_naive_starves() {
    // The acceptance claim over the fig_service thrash mix at 4
    // concurrent tenants (1 scanner + 3 reuse tenants).
    let base = fig_service::base_config(&StackConfig::k40c_p3700());
    let jobs_kinds: Vec<(JobSpec, &str)> =
        (0..4).map(|i| fig_service::job_for("thrash", i, 1)).collect();

    // Solo baseline p99 per job, on the same base stack.
    let solo_svc = Service::new(&base).unwrap();
    let solo_p99: Vec<f64> = jobs_kinds
        .iter()
        .map(|(job, _)| {
            let run = solo_svc.run_sim(std::slice::from_ref(job)).unwrap();
            run.report.tenants[0].latency_p(99.0)
        })
        .collect();
    let jobs: Vec<JobSpec> = jobs_kinds.into_iter().map(|(j, _)| j).collect();

    let run_mode = |mode: &str| {
        let cfg = fig_service::mode_config(&base, mode, 4);
        Service::new(&cfg).unwrap().run_sim(&jobs).unwrap().report
    };

    let naive = run_mode("naive");
    let isolated = run_mode("isolated");

    let ratios = |r: &gpufs_ra::gpufs::RunReport| -> Vec<f64> {
        r.tenants
            .iter()
            .zip(&solo_p99)
            .map(|(t, s)| t.latency_p(99.0) / s.max(1.0))
            .collect()
    };
    let naive_ratios = ratios(&naive);
    let isolated_ratios = ratios(&isolated);

    // Isolated: nobody starves — every tenant within 2x its solo p99.
    for (i, r) in isolated_ratios.iter().enumerate() {
        assert!(
            *r <= 2.0,
            "isolated tenant {i} ({}) p99 is {r:.2}x its solo run \
             (isolated {:?} / naive {:?})",
            isolated.tenants[i].tenant,
            isolated_ratios,
            naive_ratios,
        );
    }
    // Naive: at least one tenant starved beyond 2x (in practice the
    // reuse tenants blow out by orders of magnitude once the scan
    // flushes their resident sets).
    assert!(
        naive_ratios.iter().any(|r| *r > 2.0),
        "naive mode starved nobody: {naive_ratios:?}"
    );
    // The mechanism: tenant-aware victim selection actually fired, and
    // the protected reuse tenants kept their pages.
    assert!(
        isolated.cache.tenant_evictions > 0,
        "tenant-aware replacement never picked a quota victim"
    );
    assert!(
        gpufs_ra::service::fairness_ratio(&isolated.tenants, 99.0)
            < gpufs_ra::service::fairness_ratio(&naive.tenants, 99.0),
        "isolation must improve the p99 fairness ratio"
    );
    // Every tenant delivered its bytes in both modes.
    for r in [&naive, &isolated] {
        for t in &r.tenants {
            assert!(t.bytes > 0);
            assert!(!t.latency_ns.is_empty());
        }
    }
}

#[test]
fn partitioned_budget_narrows_prefetch_grants() {
    let mut cfg = fig_service::base_config(&StackConfig::k40c_p3700());
    cfg.service.max_jobs = 2;
    let jobs: Vec<JobSpec> = (0..2)
        .map(|i| fig_service::job_for("sequential", i, 1).0)
        .collect();

    let max_grant = |cfg: &StackConfig| -> u64 {
        let run = Service::new(cfg).unwrap().run_sim_with_grants(&jobs).unwrap();
        run.report
            .grants
            .iter()
            .flatten()
            .map(|g| g.prefetch)
            .max()
            .unwrap_or(0)
    };
    let shared = max_grant(&cfg);
    cfg.service.budget = ServiceBudget::Partitioned;
    let partitioned = max_grant(&cfg);
    assert_eq!(shared, 64 * KIB, "shared budget grants the full window");
    assert_eq!(
        partitioned,
        32 * KIB,
        "partitioned budget splits the window across 2 tenants"
    );
}

// ------------------------------------------------------------- live

fn live_seq_job(tenant: &str, path: std::path::PathBuf, bytes: u64, tbs: u64) -> LiveJobSpec {
    let ps = 4 * KIB;
    let stride = bytes / tbs;
    // Salt content by tenant name: identical file bytes would let a
    // cross-tenant mix-up checksum clean.
    let salt = tenant
        .bytes()
        .fold(0u64, |a, b| a.wrapping_mul(31).wrapping_add(b as u64));
    ensure_test_file_seeded(&path, bytes, salt).unwrap();
    LiveJobSpec {
        tenant: tenant.into(),
        files: vec![LiveFile {
            path,
            spec: FileSpec::read_only(bytes),
        }],
        programs: (0..tbs)
            .map(|tb| TbProgram {
                reads: (0..stride / ps)
                    .map(|i| Gread {
                        file: FileId(0),
                        offset: tb * stride + i * ps,
                        len: ps,
                    })
                    .collect(),
                compute_ns_per_read: 0,
                rmw: false,
            })
            .collect(),
    }
}

#[test]
fn live_service_two_concurrent_tenants_verify_and_account() {
    let mut cfg = StackConfig::k40c_p3700();
    cfg.engine = EngineKind::Live;
    cfg.gpufs.prefetch_size = 64 * KIB;
    cfg.service.max_jobs = 2;
    let dir = std::env::temp_dir();
    let bytes = 512 * KIB;
    let jobs = vec![
        live_seq_job("a", dir.join("gpufs_ra_svc_live_a.bin"), bytes, 4),
        live_seq_job("b", dir.join("gpufs_ra_svc_live_b.bin"), bytes, 4),
    ];
    let run = Service::new(&cfg).unwrap().run_live(&jobs, true).unwrap();
    assert_eq!(run.checksum_ok.len(), 2);
    assert!(run.all_checksums_ok(), "per-tenant checksums must verify");
    let r = &run.run.report;
    assert_eq!(r.tenants.len(), 2);
    assert_eq!(r.bytes, 2 * bytes);
    for t in &r.tenants {
        assert_eq!(t.bytes, bytes);
        assert_eq!(t.admitted_ns, 0, "both jobs admitted immediately");
        assert!(t.done_ns > 0);
        assert_eq!(
            t.latency_ns.count(),
            bytes / (4 * KIB),
            "one latency sample per gread"
        );
        assert!(t.latency_p(99.0) >= t.latency_p(50.0));
    }
    assert!(r.prefetch.buffer_hits > 0, "prefetcher engaged under the service");
}

#[test]
fn live_service_max_jobs_1_queues_the_second_tenant() {
    let mut cfg = StackConfig::k40c_p3700();
    cfg.engine = EngineKind::Live;
    cfg.service.max_jobs = 1;
    let dir = std::env::temp_dir();
    let bytes = 256 * KIB;
    let jobs = vec![
        live_seq_job("first", dir.join("gpufs_ra_svc_adm_a.bin"), bytes, 2),
        live_seq_job("second", dir.join("gpufs_ra_svc_adm_b.bin"), bytes, 2),
    ];
    let run = Service::new(&cfg).unwrap().run_live(&jobs, true).unwrap();
    assert!(run.all_checksums_ok());
    let t = &run.run.report.tenants;
    assert_eq!(t[0].admitted_ns, 0);
    assert!(
        t[1].admitted_ns >= t[0].done_ns,
        "second job admitted at {} before the first finished at {}",
        t[1].admitted_ns,
        t[0].done_ns
    );
    assert!(t[1].wait_ns() > 0, "queued job accounts wall-clock wait");
    assert_eq!(t[0].bytes, bytes);
    assert_eq!(t[1].bytes, bytes);
}
